"""Shared neural-net building blocks (pure JAX, no flax).

Parameters are plain pytrees (nested dicts of jnp arrays). Every ``init_*``
has a matching ``spec_*`` in :mod:`repro.sharding.rules` that mirrors the
tree with :class:`jax.sharding.PartitionSpec` leaves.

Attention is implemented three ways:
  * ``naive``   — materialize the (S, S) score matrix (small shapes, oracle),
  * ``chunked`` — jnp flash attention: double ``lax.scan`` over query/key
    blocks with an online softmax; O(S·block) memory, lowers on any backend.
    This is the default for the CPU-hosted dry-run.
  * ``pallas``  — the TPU Pallas kernel in :mod:`repro.kernels.flash_attention`
    (validated against ``naive`` in interpret mode; selected on real TPUs).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

# ----------------------------------------------------------------------
# initializers
# ----------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float = 1.0):
    """Truncated-normal fan-in init (LeCun-style)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / math.sqrt(fan_in)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def embed_init(key, shape, dtype, scale: float = 1.0):
    std = scale / math.sqrt(shape[-1])
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


# ----------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------

def init_norm(key, d, dtype, kind: str = "rmsnorm"):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = x32.mean(-1, keepdims=True)
        var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
        y = (x32 - mu) * lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)
                + p["bias"].astype(jnp.float32)).astype(dt)
    ms = (x32 * x32).mean(-1, keepdims=True)
    y = x32 * lax.rsqrt(ms + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# MLP
# ----------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, dtype, kind: str = "swiglu"):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (d_model, d_ff), dtype),
            "w_up": dense_init(ks[1], (d_model, d_ff), dtype),
            "w_down": dense_init(ks[2], (d_ff, d_model), dtype),
        }
    return {  # gelu MLP (starcoder2 / whisper style)
        "w_up": dense_init(ks[0], (d_model, d_ff), dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), dtype),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def _gathered(w, cfg, *spec):
    """FSDP weight-gather on use: re-constrain the weight so the 'data'
    shard dim is gathered (weights are small; the alternative — computing
    with a sharded contraction dim — all-reduces the much larger
    activations). Active only when cfg.fsdp_gather_weights."""
    if cfg is None or not getattr(cfg, "fsdp_gather_weights", False):
        return w
    from repro.sharding.constrain import maybe_constrain
    return maybe_constrain(w, *spec)


def apply_mlp(p, x, cfg=None):
    if "w_gate" in p:
        h = jax.nn.silu(x @ _gathered(p["w_gate"], cfg, None, "model")) \
            * (x @ _gathered(p["w_up"], cfg, None, "model"))
        return h @ _gathered(p["w_down"], cfg, "model", None)
    h = jax.nn.gelu(x @ _gathered(p["w_up"], cfg, None, "model") + p["b_up"])
    return h @ _gathered(p["w_down"], cfg, "model", None) + p["b_down"]


# ----------------------------------------------------------------------
# attention (GQA, causal, optional sliding window)
# ----------------------------------------------------------------------

def init_attention(key, cfg):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dt),
        "wk": dense_init(ks[1], (d, kv * hd), dt),
        "wv": dense_init(ks[2], (d, kv * hd), dt),
        "wo": dense_init(ks[3], (h * hd, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
    return p


def _qkv(p, x, cfg):
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = x @ _gathered(p["wq"], cfg, None, "model") \
        + (p["bq"] if "bq" in p else 0.0)
    k = x @ _gathered(p["wk"], cfg, None, "model") \
        + (p["bk"] if "bk" in p else 0.0)
    v = x @ _gathered(p["wv"], cfg, None, "model") \
        + (p["bv"] if "bv" in p else 0.0)
    B, S = x.shape[0], x.shape[1]
    return (q.reshape(B, S, h, hd), k.reshape(B, S, kv, hd),
            v.reshape(B, S, kv, hd))


def _expand_kv(k, num_heads):
    """(B, S, KV, hd) -> (B, S, H, hd) by repeating groups."""
    B, S, KV, hd = k.shape
    rep = num_heads // KV if num_heads % KV == 0 else -(-num_heads // KV)
    k = jnp.repeat(k, rep, axis=2)
    return k[:, :, :num_heads]


def naive_attention(q, k, v, *, causal: bool, window: int = 0,
                    q_offset: int = 0) -> jnp.ndarray:
    """Reference attention. q:(B,Sq,H,hd) k,v:(B,Sk,H,hd)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32)).astype(q.dtype)


# ---------------- jnp flash attention with custom VJP -------------------
# The naive scan-based "flash" saves every (bq, bk) probability block for
# autodiff — i.e. the full S^2 attention matrix, defeating the point. This
# implementation attaches a custom VJP that recomputes the blocks in the
# backward pass (the flash-attention backward), so train-time memory is
# O(S·hd + S) per head. Layout inside is (B, H, S, hd); batch is pinned to
# the 'data' mesh axis and heads to 'model' via sharding constraints.

def _blockify(x, blk):
    """(B, H, S, hd) -> (n, B, H, blk, hd), padding S to a multiple."""
    B, H, S, hd = x.shape
    pad = (-S) % blk
    x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n = x.shape[2] // blk
    return x.reshape(B, H, n, blk, hd).transpose(2, 0, 1, 3, 4)


def _unblockify(xb, S):
    """(n, B, H, blk, hd) -> (B, H, S, hd)."""
    n, B, H, blk, hd = xb.shape
    return xb.transpose(1, 2, 0, 3, 4).reshape(B, H, n * blk, hd)[:, :, :S]


def _block_mask(qi, ki, bq, bk, *, causal, window, sk, q_offset):
    qpos = qi * bq + jnp.arange(bq)[:, None] + q_offset
    kpos = ki * bk + jnp.arange(bk)[None, :]
    m = kpos < sk
    if causal:
        m = m & (kpos <= qpos)
    if window > 0:
        m = m & (kpos > qpos - window)
    return m                                            # (bq, bk)


def _flash_fwd_impl(q, k, v, causal, window, bq, bk, q_offset):
    """q,k,v: (B,H,S,hd). Returns (out (B,H,Sq,hd), lse (B,H,Sq))."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    qb = _blockify(q, bq)                               # (nq,B,H,bq,hd)
    kb = _blockify(k, bk)
    vb = _blockify(v, bk)
    nq, nk = qb.shape[0], kb.shape[0]

    def q_step(_, inp):
        qi, qblk = inp

        def kv_step(carry, kinp):
            m, l, acc = carry
            ki, kblk, vblk = kinp
            s = jnp.einsum("bhqd,bhkd->bhqk", qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * scale
            msk = _block_mask(qi, ki, bq, bk, causal=causal, window=window,
                              sk=Sk, q_offset=q_offset)
            s = jnp.where(msk[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(msk[None, None], p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((B, H, bq), jnp.float32)
        a0 = jnp.zeros((B, H, bq, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0),
                                  (jnp.arange(nk), kb, vb))
        out = (acc / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), 1e30)
        return None, (out, lse)

    _, (ob, lseb) = lax.scan(q_step, None, (jnp.arange(nq), qb))
    out = _unblockify(ob, Sq)
    lse = lseb.transpose(1, 2, 0, 3).reshape(B, H, -1)[:, :, :Sq]
    return out, lse


def _flash_bwd_impl(q, k, v, out, lse, dout, causal, window, bq, bk,
                    q_offset):
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), -1)
    qb = _blockify(q, bq)
    dob = _blockify(dout, bq)
    kb = _blockify(k, bk)
    vb = _blockify(v, bk)
    nq, nk = qb.shape[0], kb.shape[0]
    pad_q = nq * bq - Sq

    def pad_row(x):  # (B,H,Sq) -> (nq,B,H,bq)
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad_q)))
        return x.reshape(B, H, nq, bq).transpose(2, 0, 1, 3)

    lseb = pad_row(lse)
    deltab = pad_row(delta)

    def kv_step(dq, kinp):
        ki, kblk, vblk = kinp
        kf = kblk.astype(jnp.float32)
        vf = vblk.astype(jnp.float32)

        def q_step(carry, qinp):
            dkj, dvj, dq = carry
            qi, qblk, doblk, lse_i, del_i = qinp
            qf = qblk.astype(jnp.float32)
            dof = doblk.astype(jnp.float32)
            s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
            msk = _block_mask(qi, ki, bq, bk, causal=causal, window=window,
                              sk=Sk, q_offset=q_offset)
            p = jnp.exp(s - lse_i[..., None])
            p = jnp.where(msk[None, None], p, 0.0)
            dvj = dvj + jnp.einsum("bhqk,bhqd->bhkd", p, dof)
            dp = jnp.einsum("bhqd,bhkd->bhqk", dof, vf)
            ds = p * (dp - del_i[..., None]) * scale
            dkj = dkj + jnp.einsum("bhqk,bhqd->bhkd", ds, qf)
            dq = dq.at[qi].add(jnp.einsum("bhqk,bhkd->bhqd", ds, kf))
            return (dkj, dvj, dq), None

        z = jnp.zeros((B, H, bk, hd), jnp.float32)
        (dkj, dvj, dq), _ = lax.scan(
            q_step, (z, z, dq), (jnp.arange(nq), qb, dob, lseb, deltab))
        return dq, (dkj, dvj)

    dq0 = jnp.zeros((nq, B, H, bq, hd), jnp.float32)
    dq, (dkb, dvb) = lax.scan(kv_step, dq0, (jnp.arange(nk), kb, vb))
    dqf = _unblockify(dq, Sq).astype(q.dtype)
    dkf = _unblockify(dkb, Sk).astype(k.dtype)
    dvf = _unblockify(dvb, Sk).astype(v.dtype)
    return dqf, dkf, dvf


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_mha(q, k, v, causal, window, bq, bk, q_offset):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, bq, bk, q_offset)
    return out


def _flash_mha_fwd(q, k, v, causal, window, bq, bk, q_offset):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, bq, bk, q_offset)
    return out, (q, k, v, out, lse)


def _flash_mha_bwd(causal, window, bq, bk, q_offset, res, dout):
    q, k, v, out, lse = res
    return _flash_bwd_impl(q, k, v, out, lse, dout, causal, window, bq, bk,
                           q_offset)


_flash_mha.defvjp(_flash_mha_fwd, _flash_mha_bwd)


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      q_block: int = 512, kv_block: int = 1024,
                      q_offset: int = 0) -> jnp.ndarray:
    """Flash attention in jnp with an exact-memory custom VJP.

    q, k, v: (B, S, H, hd) (kv pre-expanded to H heads). Returns same layout.
    """
    from repro.sharding.constrain import maybe_constrain
    Sq, Sk = q.shape[1], k.shape[1]
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    # (B,S,H,hd) -> (B,H,S,hd), pin batch->data, heads->model
    qt = maybe_constrain(q.transpose(0, 2, 1, 3), "data", "model", None, None)
    kt = maybe_constrain(k.transpose(0, 2, 1, 3), "data", "model", None, None)
    vt = maybe_constrain(v.transpose(0, 2, 1, 3), "data", "model", None, None)
    out = _flash_mha(qt, kt, vt, causal, window, q_block, kv_block, q_offset)
    out = maybe_constrain(out, "data", "model", None, None)
    return out.transpose(0, 2, 1, 3)


def attention_train(p, x, cfg, *, causal: bool = True,
                    positions: Optional[jnp.ndarray] = None,
                    kv_override=None):
    """Full-sequence attention (train / prefill). kv_override supplies
    external K/V inputs (cross-attention)."""
    B, S, _ = x.shape
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(p, x, cfg)
    if kv_override is not None:
        k, v = kv_override  # already (B,Sk,KV,hd)
    elif cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    win = cfg.sliding_window
    if cfg.attn_impl == "naive" or S <= 1024:
        o = naive_attention(q, k, v, causal=causal, window=win)
    elif cfg.attn_impl == "pallas":
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, k, v, causal=causal, window=win)
    else:
        o = chunked_attention(q, k, v, causal=causal, window=win)
    o = o.reshape(B, S, h * hd)
    return o @ _gathered(p["wo"], cfg, "model", None)


# ---------------- decode (single new token against a KV cache) -----------

def init_kv_cache(cfg, batch, cache_len, layers_leading=()):
    """Allocate a KV cache. Sliding-window archs use a ring buffer of
    min(window, cache_len). Optional int8 quantized storage."""
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    eff = min(cfg.sliding_window, cache_len) if cfg.sliding_window else cache_len
    shape = (*layers_leading, batch, eff, kv, hd)
    if cfg.resolved_kv_cache_dtype == "int8":
        c = {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros((*layers_leading, batch, eff, kv), jnp.float32),
            "v_scale": jnp.zeros((*layers_leading, batch, eff, kv), jnp.float32),
        }
    else:
        dt = jnp.dtype(cfg.resolved_kv_cache_dtype)
        c = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    return c


def _quantize_kv(x):
    """(B,1,KV,hd) -> int8 values + per-(token,head) scale."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0].astype(jnp.float32)


def _dequantize_kv(q, scale):
    return q.astype(jnp.float32) * scale[..., None]


def update_kv_cache(cache, k_new, v_new, pos, cfg):
    """Insert one token at position pos (ring-buffered for sliding window)."""
    eff = cache["k"].shape[-3]
    slot = jnp.mod(pos, eff)
    if "k_scale" in cache:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        cache = {
            "k": lax.dynamic_update_slice_in_dim(cache["k"], kq, slot, axis=-3),
            "v": lax.dynamic_update_slice_in_dim(cache["v"], vq, slot, axis=-3),
            "k_scale": lax.dynamic_update_slice_in_dim(
                cache["k_scale"], ks, slot, axis=-2),
            "v_scale": lax.dynamic_update_slice_in_dim(
                cache["v_scale"], vs, slot, axis=-2),
        }
    else:
        dt = cache["k"].dtype
        cache = {
            "k": lax.dynamic_update_slice_in_dim(
                cache["k"], k_new.astype(dt), slot, axis=-3),
            "v": lax.dynamic_update_slice_in_dim(
                cache["v"], v_new.astype(dt), slot, axis=-3),
        }
    return cache


def attention_decode(p, x, cache, pos, cfg, *, cross: bool = False,
                     cross_len: Optional[jnp.ndarray] = None):
    """One-token attention against the cache.

    x: (B, 1, D). pos: scalar current position. Returns (out, new_cache).
    For cross-attention the cache holds precomputed encoder K/V and is not
    updated.
    """
    B = x.shape[0]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"] + (p["bq"] if "bq" in p else 0.0)).reshape(B, 1, h, hd)
    if not cross:
        k_new = (x @ p["wk"] + (p["bk"] if "bk" in p else 0.0)).reshape(B, 1, kv, hd)
        v_new = (x @ p["wv"] + (p["bv"] if "bv" in p else 0.0)).reshape(B, 1, kv, hd)
        if cfg.rope_theta > 0:
            posv = jnp.full((B, 1), pos)
            q = apply_rope(q, posv, cfg.rope_theta)
            k_new = apply_rope(k_new, posv, cfg.rope_theta)
        cache = update_kv_cache(cache, k_new, v_new, pos, cfg)
    if "k_scale" in cache:
        kc = _dequantize_kv(cache["k"], cache["k_scale"])
        vc = _dequantize_kv(cache["v"], cache["v_scale"])
    else:
        kc, vc = cache["k"], cache["v"]
    eff = kc.shape[-3]
    # validity of each cache slot
    slot_idx = jnp.arange(eff)
    if cross:
        valid = slot_idx < (cross_len if cross_len is not None else eff)
    elif cfg.sliding_window and cfg.sliding_window <= eff:
        valid = slot_idx < jnp.minimum(pos + 1, eff)   # ring buffer fully valid once warm
    else:
        valid = slot_idx <= pos
    kc = _expand_kv(kc, h)                              # (B, eff, H, hd)
    vc = _expand_kv(vc, h)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kc.astype(jnp.float32)) / math.sqrt(hd)
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, vc.astype(jnp.float32))
    o = o.astype(x.dtype).reshape(B, 1, h * hd)
    return o @ p["wo"], cache


# ----------------------------------------------------------------------
# losses
# ----------------------------------------------------------------------

def chunked_softmax_xent(logits_fn, x_final, w_head, labels, mask,
                         chunk: int = 256):
    """Cross-entropy with the vocab projection fused per sequence chunk so
    the (B, S, V) logits tensor is never fully materialized.

    x_final: (B, S, D) final hidden states; w_head: (D, V).
    labels, mask: (B, S).
    """
    from repro.sharding.constrain import maybe_constrain
    B, S, D = x_final.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    xs = jnp.pad(x_final, ((0, 0), (0, pad), (0, 0)))
    ls = jnp.pad(labels, ((0, 0), (0, pad)))
    ms = jnp.pad(mask, ((0, 0), (0, pad)))
    n = xs.shape[1] // chunk
    xs = xs.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    ls = ls.reshape(B, n, chunk).transpose(1, 0, 2)
    ms = ms.reshape(B, n, chunk).transpose(1, 0, 2)
    xs = maybe_constrain(xs, None, "data", None, None)

    # checkpointed body: the (B, chunk, V) logits block is recomputed in the
    # backward pass instead of being stacked across the scan (which would
    # materialize the full (B, S, V) logits tensor).
    @partial(jax.checkpoint, prevent_cse=False)
    def step(carry, xlm):
        tot, cnt = carry
        xc, lc, mc = xlm
        logits = (xc @ w_head).astype(jnp.float32)          # (B, chunk, V)
        logits = maybe_constrain(logits, "data", None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (tot + nll.sum(), cnt + mc.sum()), None

    (tot, cnt), _ = lax.scan(step, (jnp.float32(0.0), jnp.float32(0.0)),
                             (xs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)
