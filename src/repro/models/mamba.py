"""Mamba (selective SSM) mixer block — jamba's recurrent layer.

Training/prefill uses a *chunked* scan: ``lax.scan`` over sequence chunks
carrying the SSM state, with an associative scan inside each chunk. The live
hidden-state buffer is O(B · chunk · d_inner · d_state) instead of
O(B · S · d_inner · d_state) — this is the TPU adaptation of the CUDA
selective-scan kernel (VMEM-sized chunks instead of SM shared-memory tiles).

Decode is the exact single-step recurrence with a (conv_state, ssm_state)
cache.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init
from repro.sharding.constrain import maybe_constrain


def init_mamba(key, cfg):
    d = cfg.d_model
    di = cfg.mamba_d_inner
    ds = cfg.mamba_d_state
    dc = cfg.mamba_d_conv
    dr = cfg.dt_rank
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    # S4D-real initialization for A
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "w_in": dense_init(ks[0], (d, 2 * di), dt),
        "conv_w": dense_init(ks[1], (dc, di), dt),       # depthwise causal conv
        "conv_b": jnp.zeros((di,), dt),
        "w_x_dbc": dense_init(ks[2], (di, dr + 2 * ds), dt),
        "w_dt": dense_init(ks[3], (dr, di), dt),
        "b_dt": jnp.log(jnp.expm1(0.01)) * jnp.ones((di,), jnp.float32),
        "a_log": jnp.log(a),                              # (di, ds) fp32
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[4], (di, d), dt),
    }


def _ssm_params(p, x, cfg):
    """x: (..., di) conv+silu output -> (dt, B, C) selective params."""
    dr, ds = cfg.dt_rank, cfg.mamba_d_state
    dbc = x @ p["w_x_dbc"]
    dt_r, b, c = jnp.split(dbc, [dr, dr + ds], axis=-1)
    dt = jax.nn.softplus(dt_r.astype(jnp.float32) @ p["w_dt"].astype(jnp.float32)
                         + p["b_dt"])                     # (..., di)
    return dt, b.astype(jnp.float32), c.astype(jnp.float32)


def _causal_conv(x, w, b):
    """x: (B, S, di); depthwise causal conv along S with kernel (dc, di)."""
    dc = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    # sum_{j} x[t-dc+1+j] * w[j]
    out = sum(xp[:, j:j + x.shape[1]] * w[j] for j in range(dc))
    return out + b


def apply_mamba(p, x, cfg, *, chunk: int = 256):
    """Full-sequence mamba mixer. x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    di, ds = cfg.mamba_d_inner, cfg.mamba_d_state
    xz = x @ p["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)                     # (B,S,di)
    xi = maybe_constrain(xi, "data", None, "model")
    xi = jax.nn.silu(_causal_conv(xi, p["conv_w"], p["conv_b"]))
    dt, bmat, cmat = _ssm_params(p, xi, cfg)              # (B,S,di),(B,S,ds),(B,S,ds)
    a = -jnp.exp(p["a_log"])                              # (di, ds)

    # discretize: da[t] = exp(dt[t] * A) (di,ds);  db_x[t] = dt*B[t]*x[t]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    def padS(t):
        return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
    xi_, dt_, b_, c_ = map(padS, (xi.astype(jnp.float32), dt, bmat, cmat))
    n = xi_.shape[1] // chunk
    resh = lambda t: t.reshape(B, n, chunk, *t.shape[2:]).swapaxes(0, 1)
    xi_, dt_, b_, c_ = map(resh, (xi_, dt_, b_, c_))      # (n,B,chunk,...)

    @partial(jax.checkpoint, prevent_cse=False)
    def chunk_step(h, inp):
        xc, dtc, bc, cc = inp                             # (B,chunk,di),(B,chunk,di),(B,chunk,ds)
        da = jnp.exp(dtc[..., None] * a)                  # (B,chunk,di,ds)
        da = maybe_constrain(da, "data", None, "model", None)
        dbx = (dtc * xc)[..., None] * bc[..., None, :]    # (B,chunk,di,ds)
        dbx = maybe_constrain(dbx, "data", None, "model", None)

        def assoc(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2

        # prepend carry as the first element
        da0 = jnp.concatenate([jnp.ones_like(da[:, :1]), da], axis=1)
        dbx0 = jnp.concatenate([h[:, None], dbx], axis=1)
        _, hs = lax.associative_scan(assoc, (da0, dbx0), axis=1)
        hs = hs[:, 1:]                                     # (B,chunk,di,ds)
        y = jnp.einsum("bcds,bcs->bcd", hs, cc)           # (B,chunk,di)
        return hs[:, -1], y

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    _, ys = lax.scan(chunk_step, h0, (xi_, dt_, b_, c_))  # (n,B,chunk,di)
    y = ys.swapaxes(0, 1).reshape(B, n * chunk, di)[:, :S]
    y = y + xi.astype(jnp.float32) * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ p["w_out"]


# ---------------------------- decode ----------------------------------

def init_mamba_cache(cfg, batch, layers_leading=()):
    di, ds, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    return {
        "conv": jnp.zeros((*layers_leading, batch, dc - 1, di),
                          jnp.dtype(cfg.dtype)),
        "ssm": jnp.zeros((*layers_leading, batch, di, ds), jnp.float32),
    }


def decode_mamba(p, x, cache, cfg):
    """One-token mamba step. x: (B, 1, D) -> (out, new_cache)."""
    B = x.shape[0]
    di, ds = cfg.mamba_d_inner, cfg.mamba_d_state
    xz = x[:, 0] @ p["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)                     # (B, di)
    # conv ring: state holds previous dc-1 inputs
    conv_in = jnp.concatenate([cache["conv"], xi[:, None]], axis=1)  # (B,dc,di)
    w = p["conv_w"]                                       # (dc, di)
    xc = jnp.einsum("bcd,cd->bd", conv_in, w) + p["conv_b"]
    xc = jax.nn.silu(xc)
    dt, bvec, cvec = _ssm_params(p, xc, cfg)              # (B,di),(B,ds),(B,ds)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt[..., None] * a)                       # (B,di,ds)
    h = cache["ssm"] * da + (dt * xc.astype(jnp.float32))[..., None] \
        * bvec[:, None, :]
    y = jnp.einsum("bds,bs->bd", h, cvec)
    y = y + xc.astype(jnp.float32) * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = (y @ p["w_out"])[:, None]
    return out, {"conv": conv_in[:, 1:], "ssm": h}
