"""The paper's three CNN models (§V-A footnotes), in pure JAX.

  * CNN-MNIST : 5x5x10 conv -> 2x2 maxpool -> 5x5x20 conv -> (dropout) ->
                2x2 maxpool -> flatten -> fc 320x50 -> (dropout) -> fc 50x10
  * CNN-FMNIST: 5x5x16 conv -> BN -> 2x2 maxpool -> 5x5x32 conv -> BN ->
                2x2 maxpool -> flatten -> fc 1568x10
  * CNN-CIFAR : 5x5x6 conv -> 2x2 maxpool -> 5x5x16 conv -> flatten ->
                fc 400x120 -> fc 120x84 -> fc 84x10

Dropout is treated as identity at selection/evaluation time (the paper's
selection signal is the *initial gradient*, which it computes in eval-style
passes); batch-norm uses per-batch statistics (no running stats needed for
the FL simulation's short local epochs).

These are the federated local models for the paper-faithful reproduction.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _conv_init(key, shape):  # (kh, kw, cin, cout)
    fan_in = shape[0] * shape[1] * shape[2]
    std = 1.0 / math.sqrt(fan_in)
    return jax.random.uniform(key, shape, jnp.float32, -std, std)


def _fc_init(key, shape):
    std = 1.0 / math.sqrt(shape[0])
    return jax.random.uniform(key, shape, jnp.float32, -std, std)


def conv2d(x, w, b, padding="VALID"):
    """x: (B,H,W,C); w: (kh,kw,cin,cout) — im2col formulation.

    Expressing the conv as patches @ w lowers to one GEMM: on CPU this is
    ~2x faster (forward+backward) than lax.conv for these 5x5 kernels,
    and under the cohort engine's per-client vmap it becomes a batched
    GEMM instead of XLA's slow grouped-convolution path.
    """
    kh, kw, cin, cout = w.shape
    if padding == "SAME":
        ph, pw = (kh - 1) // 2, (kw - 1) // 2
        x = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw),
                        (0, 0)))
    oh, ow = x.shape[1] - kh + 1, x.shape[2] - kw + 1
    cols = [x[:, i:i + oh, j:j + ow, :]
            for i in range(kh) for j in range(kw)]
    patches = jnp.concatenate(cols, axis=-1)     # (B, oh, ow, kh*kw*cin)
    return patches @ w.reshape(kh * kw * cin, cout) + b


def conv2d_lax(x, w, b, padding="VALID"):
    """Reference lax.conv path (oracle for conv2d's im2col rewrite)."""
    y = lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def maxpool2(x):
    """2x2/stride-2 max pool via reshape (gradient avoids the slow
    select-and-scatter path of reduce_window; VALID semantics)."""
    b, h, w, c = x.shape
    if h % 2 or w % 2:
        x = x[:, :h - h % 2, :w - w % 2, :]
        b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max((2, 4))


def batchnorm(x, scale, bias, eps=1e-5):
    mu = x.mean((0, 1, 2), keepdims=True)
    var = x.var((0, 1, 2), keepdims=True)
    return (x - mu) * lax.rsqrt(var + eps) * scale + bias


# ----------------------------------------------------------------------

def init_cnn(key, variant: str) -> dict:
    ks = jax.random.split(key, 12)
    if variant == "mnist":
        return {
            "c1_w": _conv_init(ks[0], (5, 5, 1, 10)), "c1_b": jnp.zeros(10),
            "c2_w": _conv_init(ks[1], (5, 5, 10, 20)), "c2_b": jnp.zeros(20),
            "f1_w": _fc_init(ks[2], (320, 50)), "f1_b": jnp.zeros(50),
            "f2_w": _fc_init(ks[3], (50, 10)), "f2_b": jnp.zeros(10),
        }
    if variant == "fmnist":
        return {
            "c1_w": _conv_init(ks[0], (5, 5, 1, 16)), "c1_b": jnp.zeros(16),
            "bn1_s": jnp.ones(16), "bn1_b": jnp.zeros(16),
            "c2_w": _conv_init(ks[1], (5, 5, 16, 32)), "c2_b": jnp.zeros(32),
            "bn2_s": jnp.ones(32), "bn2_b": jnp.zeros(32),
            "f1_w": _fc_init(ks[2], (1568, 10)), "f1_b": jnp.zeros(10),
        }
    if variant == "cifar":
        return {
            "c1_w": _conv_init(ks[0], (5, 5, 3, 6)), "c1_b": jnp.zeros(6),
            "c2_w": _conv_init(ks[1], (5, 5, 6, 16)), "c2_b": jnp.zeros(16),
            "f1_w": _fc_init(ks[2], (400, 120)), "f1_b": jnp.zeros(120),
            "f2_w": _fc_init(ks[3], (120, 84)), "f2_b": jnp.zeros(84),
            "f3_w": _fc_init(ks[4], (84, 10)), "f3_b": jnp.zeros(10),
        }
    raise ValueError(variant)


def cnn_logits(params, x, variant: str):
    """x: (B, H, W, C) float32 in [0,1]."""
    p = params
    if variant == "mnist":         # 28x28x1
        h = maxpool2(jax.nn.relu(conv2d(x, p["c1_w"], p["c1_b"])))   # 12
        h = maxpool2(jax.nn.relu(conv2d(h, p["c2_w"], p["c2_b"])))   # 4
        h = h.reshape(h.shape[0], -1)                                 # 320
        h = jax.nn.relu(h @ p["f1_w"] + p["f1_b"])
        return h @ p["f2_w"] + p["f2_b"]
    if variant == "fmnist":        # 28x28x1, SAME padding -> 7x7x32 = 1568
        h = jax.nn.relu(conv2d(x, p["c1_w"], p["c1_b"], "SAME"))
        h = maxpool2(batchnorm(h, p["bn1_s"], p["bn1_b"]))           # 14
        h = jax.nn.relu(conv2d(h, p["c2_w"], p["c2_b"], "SAME"))
        h = maxpool2(batchnorm(h, p["bn2_s"], p["bn2_b"]))           # 7
        h = h.reshape(h.shape[0], -1)                                 # 1568
        return h @ p["f1_w"] + p["f1_b"]
    if variant == "cifar":         # 32x32x3
        h = maxpool2(jax.nn.relu(conv2d(x, p["c1_w"], p["c1_b"])))   # 14
        h = maxpool2(jax.nn.relu(conv2d(h, p["c2_w"], p["c2_b"])))   # 5
        h = h.reshape(h.shape[0], -1)                                 # 400
        h = jax.nn.relu(h @ p["f1_w"] + p["f1_b"])
        h = jax.nn.relu(h @ p["f2_w"] + p["f2_b"])
        return h @ p["f3_w"] + p["f3_b"]
    raise ValueError(variant)


def image_shape(variant: str) -> Tuple[int, int, int]:
    return (32, 32, 3) if variant == "cifar" else (28, 28, 1)


def cnn_loss(params, batch, variant: str):
    logits = cnn_logits(params, batch["x"], variant)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=1)[:, 0]
    return nll.mean()


def cnn_accuracy(params, batch, variant: str):
    logits = cnn_logits(params, batch["x"], variant)
    return (logits.argmax(-1) == batch["y"]).mean()


cnn_grad = jax.jit(jax.grad(cnn_loss), static_argnames="variant")
