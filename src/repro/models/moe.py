"""Mixture-of-Experts layer (expert-parallel over the ``model`` mesh axis).

Group-local scatter dispatch (GShard-style, without the O(T·E·C) dense
dispatch tensor): tokens are split into G groups (G = the ``data`` mesh axis
size, so each group lives on one FSDP shard):

  1. per group, each (token, slot) gets a rank within its expert via a
     group-local cumulative sum — no cross-shard prefix sum;
  2. tokens are scattered into a (G, E, C, D) buffer (C = group capacity);
  3. the (G, E, C, D) -> (E, G, C, D) transpose IS the token->expert
     all-to-all (G sharded over 'data', E over 'model');
  4. experts run as a grouped einsum, results transpose back and are
     combined with the router gates.

Tokens over capacity are dropped (standard capacity-factor semantics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init
from repro.sharding.constrain import maybe_constrain


def init_moe(key, cfg):
    e = cfg.num_experts
    d = cfg.d_model
    f = cfg.d_ff_expert or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), jnp.float32),  # router in fp32
        "w_gate": dense_init(ks[1], (e, d, f), dt),
        "w_up": dense_init(ks[2], (e, d, f), dt),
        "w_down": dense_init(ks[3], (e, f, d), dt),
    }


def _num_groups(total_tokens: int) -> int:
    """Groups = data-axis size when the ambient mesh divides the tokens."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is None or am.empty or "data" not in am.axis_names:
            return 1
        g = dict(zip(am.axis_names, am.axis_sizes))["data"]
        return g if total_tokens % g == 0 else 1
    except Exception:
        return 1


def moe_capacity(tokens_per_group: int, cfg) -> int:
    per = tokens_per_group * cfg.experts_per_token / cfg.num_experts
    cap = int(per * cfg.moe_capacity_factor) + 1
    return -(-cap // 8) * 8        # multiple of 8 for tiling friendliness


def apply_moe(p, x, cfg, *, capacity: int | None = None):
    """x: (B, S, D) -> (B, S, D) plus aux losses dict."""
    B, S, D = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.experts_per_token
    G = _num_groups(T)
    Tg = T // G
    C = capacity if capacity is not None else moe_capacity(Tg, cfg)
    C = min(C, Tg * K)

    xt = x.reshape(G, Tg, D)
    xt = maybe_constrain(xt, "data", None, None)

    logits = (xt.astype(jnp.float32) @ p["router"])            # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(probs, K)                           # (G, Tg, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # --- load-balance auxiliary loss (Switch-style, global) ---
    me = probs.mean((0, 1))                                    # (E,)
    ce = jnp.zeros((E,)).at[idx.reshape(-1)].add(1.0) / (T * K)
    aux_loss = E * jnp.sum(me * ce)

    # --- group-local rank of each (token, slot) within its expert ---
    flat_e = idx.reshape(G, Tg * K)                            # (G, TgK)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # (G, TgK, E)
    ranks = jnp.cumsum(onehot, axis=1) - onehot
    rank = jnp.take_along_axis(
        ranks, flat_e[..., None], axis=2)[..., 0]              # (G, TgK)
    keep = rank < C
    slot = jnp.where(keep, flat_e * C + rank, E * C)           # drop bucket

    # --- dispatch: group-local scatter into (G, E*C+1, D) ---
    # during the scatter the model dim D is sharded over 'model' so the 16
    # tensor-parallel shards scatter disjoint D-slices instead of each
    # materializing the full buffer.
    xrep = jnp.repeat(xt, K, axis=1)                           # (G, TgK, D)
    xrep = maybe_constrain(xrep, "data", None, "model")

    def scatter_group(xr, sl):
        return jnp.zeros((E * C + 1, D), xr.dtype).at[sl].set(xr)

    buf = jax.vmap(scatter_group)(xrep, slot)                  # (G, E*C+1, D)
    buf = maybe_constrain(buf, "data", None, "model")
    h = buf[:, : E * C].reshape(G, E, C, D)
    # all-to-all: (G, E, C, D) [G:'data', D:'model'] -> (E, G, C, D)
    # [E:'model', D: full]
    h = h.transpose(1, 0, 2, 3)
    h = maybe_constrain(h, "model", "data", None, None)

    # --- expert FFN as grouped einsum (E over 'model' axis) ---
    def _g(w):
        if getattr(cfg, "fsdp_gather_weights", False):
            return maybe_constrain(w, "model", None, None)
        return w

    g = jax.nn.silu(jnp.einsum("egcd,edf->egcf", h, _g(p["w_gate"])))
    u = jnp.einsum("egcd,edf->egcf", h, _g(p["w_up"]))
    y = jnp.einsum("egcf,efd->egcd", g * u, _g(p["w_down"]))   # (E, G, C, D)
    y = maybe_constrain(y, "model", "data", None, None)

    # --- return all-to-all + group-local gather & combine (D re-sharded
    # over 'model' so the gather/combine also touch only D-slices) ---
    y = y.transpose(1, 0, 2, 3).reshape(G, E * C, D)
    y = maybe_constrain(y, "data", None, "model")
    y = jnp.concatenate([y, jnp.zeros((G, 1, D), y.dtype)], axis=1)
    out = jnp.take_along_axis(y, slot[..., None], axis=1)      # (G, TgK, D)
    w = (gates.reshape(G, Tg * K, 1).astype(y.dtype)
         * keep[..., None].astype(y.dtype))
    out = (out * w.astype(out.dtype)).reshape(G, Tg, K, D).sum(axis=2)
    out = maybe_constrain(out, "data", None, "model")
    return out.reshape(B, S, D).astype(x.dtype), \
        {"moe_aux_loss": aux_loss, "moe_drop_frac": 1.0 - keep.mean()}
