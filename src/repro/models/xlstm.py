"""xLSTM mixers: mLSTM (matrix memory, parallel/chunkwise) and sLSTM
(scalar memory, strictly sequential) [arXiv:2405.04517].

TPU adaptation: the CUDA sLSTM kernel exploits register-resident recurrence;
on TPU we express it as a ``lax.scan`` over time (the XLA while-loop keeps
state in VMEM/VREGs). The mLSTM parallel form is *chunkwise*: a scan over
sequence chunks carrying the (C, n, m) matrix-memory state with a quadratic
intra-chunk part — the same blocking idea as flash attention, sized for VMEM.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init
from repro.sharding.constrain import maybe_constrain


# ----------------------------------------------------------------------
# mLSTM
# ----------------------------------------------------------------------

def init_mlstm(key, cfg):
    d = cfg.d_model
    h = cfg.xlstm_num_heads
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    return {
        "wq": dense_init(ks[0], (d, d), dt),
        "wk": dense_init(ks[1], (d, d), dt),
        "wv": dense_init(ks[2], (d, d), dt),
        "wz": dense_init(ks[3], (d, d), dt),       # output-gating branch
        "wo": dense_init(ks[4], (d, d), dt),
        "wi": dense_init(ks[5], (d, h), jnp.float32),
        "wf": dense_init(ks[6], (d, h), jnp.float32),
        "bi": jnp.zeros((h,), jnp.float32),
        "bf": jnp.full((h,), 3.0, jnp.float32),    # open forget gates at init
    }


def _mlstm_qkv(p, x, h):
    B, S, d = x.shape
    dh = d // h
    q = (x @ p["wq"]).reshape(B, S, h, dh).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(B, S, h, dh).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(B, S, h, dh).transpose(0, 2, 1, 3)
    li = (x.astype(jnp.float32) @ p["wi"] + p["bi"]).transpose(0, 2, 1)  # (B,h,S)
    lf = jax.nn.log_sigmoid(
        x.astype(jnp.float32) @ p["wf"] + p["bf"]).transpose(0, 2, 1)
    return q, k, v, li, lf


def apply_mlstm(p, x, cfg, *, chunk: int = 256):
    """Chunkwise-parallel mLSTM. x: (B, S, D) -> (B, S, D)."""
    B, S, d = x.shape
    h = cfg.xlstm_num_heads
    dh = d // h
    q, k, v, li, lf = _mlstm_qkv(p, x, h)          # q:(B,h,S,dh)
    # xLSTM has few, wide heads (4 x 512): sharding heads over the 16-way
    # model axis pads 4 -> 16 (4x waste + permute churn); shard head_dim.
    q = maybe_constrain(q, "data", None, None, "model")
    k = maybe_constrain(k, "data", None, None, "model")
    v = maybe_constrain(v, "data", None, None, "model")
    scale = 1.0 / math.sqrt(dh)

    chunk = min(chunk, S)
    pad = (-S) % chunk
    def padt(t, fill=0.0):
        cfgp = [(0, 0)] * t.ndim
        cfgp[2] = (0, pad)
        return jnp.pad(t, cfgp, constant_values=fill)
    q, k, v = padt(q), padt(k), padt(v)
    li, lf = padt(li, -1e30), padt(lf)             # padded i-gate = -inf (no write)
    n_chunks = q.shape[2] // chunk
    resh = lambda t: t.reshape(B, h, n_chunks, chunk, *t.shape[3:]).transpose(
        2, 0, 1, 3, *range(4, t.ndim + 1))
    qc, kc, vc, lic, lfc = map(resh, (q, k, v, li, lf))  # (n,B,h,chunk[,dh])

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    @partial(jax.checkpoint, prevent_cse=False)
    def step(carry, inp):
        C, n, m = carry                             # (B,h,dk,dv),(B,h,dk),(B,h)
        qq, kk, vv, ii, ff = inp
        F = jnp.cumsum(ff, axis=-1)                 # inclusive logcum decay
        Ftot = F[..., -1]
        # log-weight of source s seen from query t: F[t]-F[s]+ff[s]... note
        # state written at s decays by F[t]-F[s]; write gain = ii[s].
        ldecay = F[..., :, None] - F[..., None, :] + ii[..., None, :]
        ldecay = jnp.where(tri, ldecay, -1e30)      # causal, s<=t
        linter = F + m[..., None]                   # decay of carried state
        m_t = jnp.maximum(linter, ldecay.max(-1))   # (B,h,chunk)
        wintra = jnp.exp(ldecay - m_t[..., None])   # (B,h,chunk,chunk)
        winter = jnp.exp(linter - m_t)              # (B,h,chunk)

        qf = qq.astype(jnp.float32) * scale
        kf, vf = kk.astype(jnp.float32), vv.astype(jnp.float32)
        s_qk = jnp.einsum("bhtd,bhsd->bhts", qf, kf) * wintra
        num = jnp.einsum("bhts,bhsv->bhtv", s_qk, vf) \
            + jnp.einsum("bhtd,bhdv->bhtv", qf, C) * winter[..., None]
        nvec = jnp.einsum("bhts,bhsd->bhtd", wintra, kf) \
            + n[..., None, :] * winter[..., None]
        den = jnp.abs(jnp.einsum("bhtd,bhtd->bht", qf, nvec))
        out = num / jnp.maximum(den, jnp.exp(-m_t))[..., None]

        # ---- state update to end of chunk ----
        m_new = jnp.maximum(Ftot + m, (Ftot[..., None] - F + ii).max(-1))
        wstate = jnp.exp(Ftot[..., None] - F + ii - m_new[..., None])
        C_new = C * jnp.exp(Ftot + m - m_new)[..., None, None] \
            + jnp.einsum("bhs,bhsd,bhsv->bhdv", wstate, kf, vf)
        n_new = n * jnp.exp(Ftot + m - m_new)[..., None] \
            + jnp.einsum("bhs,bhsd->bhd", wstate, kf)
        return (C_new, n_new, m_new), out

    C0 = jnp.zeros((B, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, h, dh), jnp.float32)
    m0 = jnp.zeros((B, h), jnp.float32)
    _, outs = lax.scan(step, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, h, n_chunks * chunk, dh)
    out = out[:, :, :S].transpose(0, 2, 1, 3).reshape(B, S, d).astype(x.dtype)
    out = out * jax.nn.silu(x @ p["wz"])
    return out @ p["wo"]


def init_mlstm_cache(cfg, batch, layers_leading=()):
    d, h = cfg.d_model, cfg.xlstm_num_heads
    dh = d // h
    return {
        "C": jnp.zeros((*layers_leading, batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((*layers_leading, batch, h, dh), jnp.float32),
        "m": jnp.zeros((*layers_leading, batch, h), jnp.float32),
    }


def decode_mlstm(p, x, cache, cfg):
    """One-token mLSTM step. x: (B,1,D)."""
    B, _, d = x.shape
    h = cfg.xlstm_num_heads
    dh = d // h
    q, k, v, li, lf = _mlstm_qkv(p, x, h)          # (B,h,1,dh), (B,h,1)
    q, k, v = q[:, :, 0], k[:, :, 0], v[:, :, 0]
    li, lf = li[..., 0], lf[..., 0]                # (B,h)
    C, n, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(lf + m, li)
    fw = jnp.exp(lf + m - m_new)
    iw = jnp.exp(li - m_new)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C_new = C * fw[..., None, None] + iw[..., None, None] \
        * kf[..., :, None] * vf[..., None, :]
    n_new = n * fw[..., None] + iw[..., None] * kf
    qf = q.astype(jnp.float32) / math.sqrt(dh)
    num = jnp.einsum("bhd,bhdv->bhv", qf, C_new)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n_new))
    out = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    out = out.reshape(B, 1, d).astype(x.dtype)
    out = out * jax.nn.silu(x @ p["wz"])
    return out @ p["wo"], {"C": C_new, "n": n_new, "m": m_new}


# ----------------------------------------------------------------------
# sLSTM
# ----------------------------------------------------------------------

def init_slstm(key, cfg):
    d = cfg.d_model
    h = cfg.xlstm_num_heads
    dh = d // h
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 9)
    p = {"wo_proj": dense_init(ks[8], (d, d), dt)}
    for i, g in enumerate(("z", "i", "f", "o")):
        p[f"w{g}"] = dense_init(ks[i], (d, d), dt)
        # per-head block-diagonal recurrent matrix
        p[f"r{g}"] = dense_init(ks[4 + i], (h, dh, dh), jnp.float32,
                                scale=0.5)
        p[f"b{g}"] = (jnp.full((d,), 3.0, jnp.float32) if g == "f"
                      else jnp.zeros((d,), jnp.float32))
    return p


def _slstm_cell(p, xg, state, h_heads):
    """One time step. xg: dict of (B, d) pre-activations from W x."""
    c, n, hprev, m = state                          # (B,H,dh) x3, (B,H,dh)
    def rec(g):
        return jnp.einsum("bhe,hed->bhd", hprev, p[f"r{g}"])
    zt = jnp.tanh(xg["z"] + rec("z"))
    it = xg["i"] + rec("i")
    ft = xg["f"] + rec("f")
    ot = jax.nn.sigmoid(xg["o"] + rec("o"))
    m_new = jnp.maximum(ft + m, it)
    iw = jnp.exp(it - m_new)
    fw = jnp.exp(ft + m - m_new)
    c_new = fw * c + iw * zt
    n_new = fw * n + iw
    h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def _slstm_pre(p, x, h):
    """Input pre-activations for all gates: (B,S,H,dh) each."""
    B, S, d = x.shape
    dh = d // h
    out = {}
    for g in ("z", "i", "f", "o"):
        out[g] = (x.astype(jnp.float32) @ p[f"w{g}"].astype(jnp.float32)
                  + p[f"b{g}"]).reshape(B, S, h, dh)
    return out


def apply_slstm(p, x, cfg):
    """Sequential sLSTM over the full sequence. x: (B,S,D)."""
    B, S, d = x.shape
    h = cfg.xlstm_num_heads
    dh = d // h
    pre = _slstm_pre(p, x, h)
    xs = {g: pre[g].transpose(1, 0, 2, 3) for g in pre}   # (S,B,H,dh)
    z0 = jnp.zeros((B, h, dh), jnp.float32)
    state0 = (z0, z0, z0, z0)

    def step(state, xg):
        return _slstm_cell(p, xg, state, h)

    _, hs = lax.scan(step, state0, xs)                    # (S,B,H,dh)
    out = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    return out @ p["wo_proj"]


def init_slstm_cache(cfg, batch, layers_leading=()):
    d, h = cfg.d_model, cfg.xlstm_num_heads
    dh = d // h

    def z():  # distinct buffers — aliasing breaks argument donation
        return jnp.zeros((*layers_leading, batch, h, dh), jnp.float32)

    return {"c": z(), "n": z(), "h": z(), "m": z()}


def decode_slstm(p, x, cache, cfg):
    B, _, d = x.shape
    h = cfg.xlstm_num_heads
    pre = _slstm_pre(p, x, h)
    xg = {g: pre[g][:, 0] for g in pre}
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    (c, n, hh, m), hnew = _slstm_cell(p, xg, state, h)
    out = hnew.reshape(B, 1, d).astype(x.dtype) @ p["wo_proj"]
    return out, {"c": c, "n": n, "h": hh, "m": m}
