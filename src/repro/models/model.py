"""Model assembly: embeddings -> scanned block groups -> norm -> LM head.

All ten assigned architectures share this spine. A config's ``cycle``
describes one period of the (possibly heterogeneous) layer stack; parameters
for each cycle position are stacked over ``num_groups`` and the stack is
applied with a single ``lax.scan`` so that even the 94-layer MoE lowers with
O(1) HLO size.

Public API:
  init_params(cfg, key)                       -> params pytree
  forward(cfg, params, tokens, ...)           -> final hidden states (B,S,D)
  loss_fn(cfg, params, batch)                 -> scalar LM loss
  init_decode_state(cfg, params, batch, L)    -> decode cache pytree
  decode_step(cfg, params, state, tok, pos)   -> (logits, new state)
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models import xlstm as X


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------

def _init_mixer(key, cfg: ModelConfig, kind: str):
    if kind == "attn":
        return L.init_attention(key, cfg)
    if kind == "mamba":
        return M.init_mamba(key, cfg)
    if kind == "mlstm":
        return X.init_mlstm(key, cfg)
    if kind == "slstm":
        return X.init_slstm(key, cfg)
    raise ValueError(kind)


def _init_block(key, cfg: ModelConfig, spec):
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "ln1": L.init_norm(ks[0], cfg.d_model, dt, cfg.norm_kind),
        "mixer": _init_mixer(ks[1], cfg, spec.mixer),
    }
    if cfg.is_encdec and spec.mixer == "attn":
        # decoder blocks get a cross-attention sublayer
        p["ln_cross"] = L.init_norm(ks[4], cfg.d_model, dt, cfg.norm_kind)
        p["cross"] = L.init_attention(ks[5], cfg)
    if spec.ffn != "none":
        p["ln2"] = L.init_norm(ks[2], cfg.d_model, dt, cfg.norm_kind)
        p["ffn"] = (MOE.init_moe(ks[3], cfg) if spec.ffn == "moe"
                    else L.init_mlp(ks[3], cfg.d_model, cfg.d_ff, dt,
                                    cfg.mlp_kind))
    return p


def _init_block_stack(key, cfg: ModelConfig, *, encoder: bool = False):
    """One stacked-param tuple, leading dim = num_groups (encoder: layers)."""
    if encoder:
        n, cycle = cfg.encoder_layers, (type(cfg.cycle[0])("attn", "mlp"),)
    else:
        n, cycle = cfg.num_groups, cfg.cycle
    blocks = []
    enc_cfg = cfg.replace(sliding_window=0) if encoder else cfg
    for pos, spec in enumerate(cycle):
        keys = jax.random.split(jax.random.fold_in(key, pos), n)
        init_one = partial(_init_block, cfg=enc_cfg, spec=spec)
        blocks.append(jax.vmap(lambda k: init_one(k))(keys))
    return tuple(blocks)


def init_params(cfg: ModelConfig, key) -> dict:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    params = {
        "tok_embed": L.embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dt),
        "blocks": _init_block_stack(ks[1], cfg),
        "final_norm": L.init_norm(ks[2], cfg.d_model, dt, cfg.norm_kind),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[3], (cfg.d_model, cfg.vocab_size), dt)
    if cfg.learned_pos:  # learned positions (whisper-style decoder)
        params["pos_embed"] = L.embed_init(ks[4], (32_768, cfg.d_model), dt)
    if cfg.is_encdec:
        params["enc"] = {
            "pos_embed": L.embed_init(ks[5], (cfg.encoder_seq, cfg.d_model), dt),
            "blocks": _init_block_stack(ks[6], cfg, encoder=True),
            "final_norm": L.init_norm(ks[7], cfg.d_model, dt, cfg.norm_kind),
        }
    return params


def lm_head_weight(cfg, params):
    return (params["tok_embed"].T if cfg.tie_embeddings
            else params["lm_head"])


# ----------------------------------------------------------------------
# forward (train / prefill)
# ----------------------------------------------------------------------

def _checkpoint_tag(cfg, t):
    """Mark a block output as savable under the save_block_out remat policy
    (saved seq-sharded so the checkpoint costs B x S/16 x D per block)."""
    if cfg.remat_policy != "save_block_out":
        return t
    from jax.ad_checkpoint import checkpoint_name

    from repro.sharding.constrain import maybe_constrain
    t = maybe_constrain(t, ("pod", "data"),
                        "model" if t.shape[1] % 16 == 0 else None, None)
    return checkpoint_name(t, "block_out")


def _apply_block(bp, x, cfg: ModelConfig, spec, *, causal: bool,
                 enc_out=None, aux=None):
    h = L.apply_norm(bp["ln1"], x, cfg.norm_eps)
    if spec.mixer == "attn":
        mixed = L.attention_train(bp["mixer"], h, cfg, causal=causal)
    elif spec.mixer == "mamba":
        mixed = M.apply_mamba(bp["mixer"], h, cfg)
    elif spec.mixer == "mlstm":
        mixed = X.apply_mlstm(bp["mixer"], h, cfg)
    else:
        mixed = X.apply_slstm(bp["mixer"], h, cfg)
    x = x + _checkpoint_tag(cfg, mixed)
    if "cross" in bp and enc_out is not None:
        h = L.apply_norm(bp["ln_cross"], x, cfg.norm_eps)
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        Bc, Sc = enc_out.shape[0], enc_out.shape[1]
        k = (enc_out @ bp["cross"]["wk"]
             + (bp["cross"].get("bk", 0.0))).reshape(Bc, Sc, kv, hd)
        v = (enc_out @ bp["cross"]["wv"]
             + (bp["cross"].get("bv", 0.0))).reshape(Bc, Sc, kv, hd)
        x = x + L.attention_train(bp["cross"], h, cfg, causal=False,
                                  kv_override=(k, v))
    if spec.ffn != "none":
        h = L.apply_norm(bp["ln2"], x, cfg.norm_eps)
        if spec.ffn == "moe":
            y, moe_aux = MOE.apply_moe(bp["ffn"], h, cfg)
            if aux is not None:
                aux["moe_aux_loss"] = aux.get("moe_aux_loss", 0.0) \
                    + moe_aux["moe_aux_loss"]
        else:
            y = L.apply_mlp(bp["ffn"], h, cfg)
        x = x + _checkpoint_tag(cfg, y)
    return x


def _run_stack(blocks, x, cfg: ModelConfig, cycle, *, causal: bool,
               enc_out=None):
    """Scan the grouped stack. Returns (x, total_moe_aux)."""

    from repro.sharding.constrain import maybe_constrain

    def one_block(bp, x, pos):
        aux = {}
        x = _apply_block(bp, x, cfg, cycle[pos], causal=causal,
                         enc_out=enc_out, aux=aux)
        return x, aux.get("moe_aux_loss", jnp.float32(0.0))

    pol = None
    if cfg.remat_policy == "save_block_out":
        pol = jax.checkpoint_policies.save_only_these_names("block_out")
    if cfg.remat:
        # nested remat: the scan body is checkpointed (saves only the per-
        # group residual-stream carry) AND each block inside is checkpointed,
        # so the backward pass holds ONE block's intermediates at a time
        # instead of a whole group's.
        one_block = jax.checkpoint(one_block, prevent_cse=False,
                                   static_argnums=(2,), policy=pol)

    # sequence-parallel activation carries (Megatron-SP analogue): the
    # residual stream saved at each scan step for the backward pass is
    # sharded (batch -> data, seq -> model); blocks re-gather the sequence
    # internally. Without this the per-layer activation checkpoints alone
    # exceed HBM on the 94-layer configs (see EXPERIMENTS.md §Perf).
    seq_ok = x.shape[1] % 16 == 0

    def group_body(carry, group_params):
        x, aux_sum = carry
        x = maybe_constrain(x, ("pod", "data"), "model" if seq_ok else None,
                            None)
        for pos in range(len(cycle)):
            x, aux = one_block(group_params[pos], x, pos)
            aux_sum = aux_sum + aux
        return (x, aux_sum), None

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(group_body, prevent_cse=True, policy=pol)
    (x, aux_sum), _ = lax.scan(body, (x, jnp.float32(0.0)), blocks)
    return x, aux_sum


def encode(cfg: ModelConfig, params, frames):
    """Whisper-style encoder over stub frame embeddings (B, Senc, D)."""
    enc = params["enc"]
    x = frames + enc["pos_embed"][None, : frames.shape[1]]
    enc_cfg = cfg.replace(sliding_window=0)
    cycle = (type(cfg.cycle[0])("attn", "mlp"),)
    x, _ = _run_stack(enc["blocks"], x, enc_cfg, cycle, causal=False)
    return L.apply_norm(enc["final_norm"], x, cfg.norm_eps)


def forward(cfg: ModelConfig, params, tokens, *, prefix_embeddings=None,
            encoder_frames=None):
    """Returns (final_hidden (B,S,D), moe_aux_loss scalar)."""
    x = params["tok_embed"][tokens]
    if prefix_embeddings is not None:
        P = prefix_embeddings.shape[1]
        x = jnp.concatenate(
            [prefix_embeddings.astype(x.dtype), x[:, P:]], axis=1)
    if cfg.learned_pos:
        x = x + params["pos_embed"][None, : x.shape[1]]
    enc_out = None
    if cfg.is_encdec:
        assert encoder_frames is not None
        enc_out = encode(cfg, params, encoder_frames)
    x, aux = _run_stack(params["blocks"], x, cfg, cfg.cycle, causal=True,
                        enc_out=enc_out)
    x = L.apply_norm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def loss_fn(cfg: ModelConfig, params, batch, *, aux_weight: float = 0.01):
    """batch: dict(tokens, labels, mask[, prefix_embeddings, encoder_frames])."""
    x, aux = forward(cfg, params, batch["tokens"],
                     prefix_embeddings=batch.get("prefix_embeddings"),
                     encoder_frames=batch.get("encoder_frames"))
    w = lm_head_weight(cfg, params)
    nll = L.chunked_softmax_xent(None, x, w, batch["labels"], batch["mask"])
    return nll + aux_weight * aux


def logits_fn(cfg: ModelConfig, params, tokens, **kw):
    x, _ = forward(cfg, params, tokens, **kw)
    return x @ lm_head_weight(cfg, params)


# ----------------------------------------------------------------------
# decode
# ----------------------------------------------------------------------

def _init_mixer_cache(cfg: ModelConfig, kind: str, batch, cache_len, n):
    lead = (n,)
    if kind == "attn":
        return L.init_kv_cache(cfg, batch, cache_len, lead)
    if kind == "mamba":
        return M.init_mamba_cache(cfg, batch, lead)
    if kind == "mlstm":
        return X.init_mlstm_cache(cfg, batch, lead)
    return X.init_slstm_cache(cfg, batch, lead)


def init_decode_state(cfg: ModelConfig, batch: int, cache_len: int):
    """Self-attention / recurrent caches, stacked per cycle position."""
    n = cfg.num_groups
    state = {"self": tuple(
        _init_mixer_cache(cfg, spec.mixer, batch, cache_len, n)
        for spec in cfg.cycle)}
    if cfg.is_encdec:
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        state["cross"] = {
            "k": jnp.zeros((n, batch, cfg.encoder_seq, kv, hd),
                           jnp.dtype(cfg.dtype)),
            "v": jnp.zeros((n, batch, cfg.encoder_seq, kv, hd),
                           jnp.dtype(cfg.dtype)),
        }
    return state


def build_cross_cache(cfg: ModelConfig, params, enc_out):
    """Precompute cross-attention K/V from encoder output (whisper prefill)."""
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    B, S, _ = enc_out.shape

    def per_group(bp):
        c = bp["cross"]
        k = (enc_out @ c["wk"] + c.get("bk", 0.0)).reshape(B, S, kv, hd)
        v = (enc_out @ c["wv"] + c.get("bv", 0.0)).reshape(B, S, kv, hd)
        return k, v

    ks, vs = jax.vmap(per_group)(params["blocks"][0])
    return {"k": ks, "v": vs}


def decode_step(cfg: ModelConfig, params, state, tokens, pos):
    """One greedy decode step.

    tokens: (B,) current token ids; pos: scalar position (int32).
    Returns (logits (B, V), new_state).
    """
    x = params["tok_embed"][tokens][:, None]              # (B,1,D)
    if cfg.learned_pos:
        x = x + lax.dynamic_slice_in_dim(
            params["pos_embed"], pos, 1, axis=0)[None]

    def group_body(x, scanned):
        group_params, group_cache, group_cross = scanned
        new_caches = []
        for p_idx, spec in enumerate(cfg.cycle):
            bp = group_params[p_idx]
            cache = group_cache[p_idx]
            h = L.apply_norm(bp["ln1"], x, cfg.norm_eps)
            if spec.mixer == "attn":
                mixed, cache = L.attention_decode(bp["mixer"], h, cache,
                                                  pos, cfg)
            elif spec.mixer == "mamba":
                mixed, cache = M.decode_mamba(bp["mixer"], h, cache, cfg)
            elif spec.mixer == "mlstm":
                mixed, cache = X.decode_mlstm(bp["mixer"], h, cache, cfg)
            else:
                mixed, cache = X.decode_slstm(bp["mixer"], h, cache, cfg)
            x = x + mixed
            if "cross" in bp and group_cross is not None:
                h = L.apply_norm(bp["ln_cross"], x, cfg.norm_eps)
                o, _ = L.attention_decode(
                    bp["cross"], h, group_cross, pos, cfg, cross=True)
                x = x + o
            if spec.ffn != "none":
                h = L.apply_norm(bp["ln2"], x, cfg.norm_eps)
                if spec.ffn == "moe":
                    y, _ = MOE.apply_moe(bp["ffn"], h, cfg)
                else:
                    y = L.apply_mlp(bp["ffn"], h)
                x = x + y
            new_caches.append(cache)
        return x, tuple(new_caches)

    cross = state.get("cross")
    x, new_self = lax.scan(group_body, x,
                           (params["blocks"], state["self"], cross))
    x = L.apply_norm(params["final_norm"], x, cfg.norm_eps)
    logits = (x[:, 0] @ lm_head_weight(cfg, params)).astype(jnp.float32)
    new_state = dict(state)
    new_state["self"] = new_self
    return logits, new_state
