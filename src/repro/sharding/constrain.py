"""Sharding-constraint helper usable from model code.

``maybe_constrain(x, axis0, axis1, ...)`` applies
``with_sharding_constraint`` when an ambient abstract mesh (set via
``jax.sharding.set_mesh``) carries the named axes; otherwise it is a no-op,
so the same model code runs in single-device tests and in the 512-device
dry-run. Axis entries may be None, a name, or a tuple of names.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


@contextlib.contextmanager
def forbid_axes(*axes):
    """Trace-time context: named axes that model-internal constraints must
    NOT use. The FL-round step vmaps cohorts over 'pod'; inner activation
    constraints mentioning 'pod' would force cross-pod resharding of
    per-cohort tensors."""
    prev = getattr(_STATE, "forbidden", frozenset())
    _STATE.forbidden = prev | set(axes)
    try:
        yield
    finally:
        _STATE.forbidden = prev


def _filter_entry(mesh_axes, entry):
    """Keep only axis names present in the mesh (tuples are filtered
    element-wise, e.g. ('pod','data') -> 'data' on the single-pod mesh)."""
    if entry is None:
        return None
    if isinstance(entry, tuple):
        kept = tuple(e for e in entry if e in mesh_axes)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]
    return entry if entry in mesh_axes else None


def maybe_constrain(x, *axes):
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:
        return x
    if am is None or am.empty:
        return x
    names = set(am.axis_names)
    names -= getattr(_STATE, "forbidden", frozenset())
    try:
        # inside a shard_map manual region the manual axes (e.g. 'pod' in
        # the FL-round step) must not appear in sharding constraints
        manual = {n for n, t in zip(am.axis_names, am.axis_types)
                  if str(t) == "Manual"}
        names -= manual
    except Exception:
        pass
    spec = P(*[_filter_entry(names, a) for a in axes])
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
