"""Partition rules: map parameter/batch/cache pytrees to PartitionSpecs.

Strategy (see DESIGN.md §5):
  * ``model`` axis — tensor parallel: attention heads / d_ff / experts / vocab.
  * ``data``  axis — FSDP: the d_model ("reduction") dimension of every large
    matrix is sharded over ``data``; GSPMD all-gathers per-layer on use.
  * ``pod``   axis — pure data parallel across FL cohorts: parameters are
    REPLICATED across pods (each pod is one federated cohort; the cross-pod
    all-reduce happens once per FL round at aggregation).

Batch dims shard over ("pod", "data"); decode caches shard batch over
``data`` when the batch is large enough, otherwise the sequence/state dim.

Rules are (regex over the tree path, rank -> PartitionSpec) pairs with a
replicate fallback, applied to shape trees from ``jax.eval_shape`` so no
memory is touched.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

D, M = "data", "model"


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


# (regex, {rank: spec}) — first match wins. Stacked block params have a
# leading num_groups dim -> specs here are written for the *unstacked* rank
# and get None prepended automatically when the leaf lives under blocks/.
_PARAM_RULES = [
    (r"tok_embed$",            {2: P(M, D)}),
    (r"lm_head$",              {2: P(D, M)}),
    (r"pos_embed$",            {2: P(None, None)}),
    # attention
    (r"(mixer|cross)/w[qkv]$", {2: P(D, M)}),
    (r"(mixer|cross)/wo$",     {2: P(M, D)}),
    (r"(mixer|cross)/b[qkv]$", {1: P(M)}),
    # dense mlp
    (r"ffn/w_(gate|up)$",      {2: P(D, M)}),
    (r"ffn/w_down$",           {2: P(M, D)}),
    (r"ffn/b_up$",             {1: P(M)}),
    (r"ffn/b_down$",           {1: P(None)}),
    # moe
    (r"ffn/router$",           {2: P(D, None)}),
    (r"ffn/w_(gate|up)$",      {3: P(M, D, None)}),
    (r"ffn/w_down$",           {3: P(M, None, D)}),
    # mamba
    (r"mixer/w_in$",           {2: P(D, M)}),
    (r"mixer/conv_w$",         {2: P(None, M)}),
    (r"mixer/conv_b$",         {1: P(M)}),
    (r"mixer/w_x_dbc$",        {2: P(M, None)}),
    (r"mixer/w_dt$",           {2: P(None, M)}),
    (r"mixer/b_dt$",           {1: P(M)}),
    (r"mixer/a_log$",          {2: P(M, None)}),
    (r"mixer/d_skip$",         {1: P(M)}),
    (r"mixer/w_out$",          {2: P(M, D)}),
    # mlstm / slstm
    (r"mixer/w[zifo]$",        {2: P(D, M)}),
    (r"mixer/wo_proj$",        {2: P(M, D)}),
    (r"mixer/r[zifo]$",        {3: P(None, None, None)}),
    (r"mixer/w[if]$",          {2: P(D, None)}),
    (r"mixer/b[if]$",          {1: P(None)}),
    # norms & anything else: replicate (fallback)
]


def _match_spec(path: str, rank: int):
    """First rule whose pattern matches AND lists this rank wins (MoE expert
    tensors share names with dense mlp weights; rank disambiguates)."""
    for pat, by_rank in _PARAM_RULES:
        if re.search(pat, path) and rank in by_rank:
            return by_rank[rank]
    return P(*([None] * rank))


def param_specs(cfg, params_shape) -> Any:
    """params_shape: pytree of ShapeDtypeStruct (from jax.eval_shape)."""

    def leaf_spec(path, leaf):
        ps = _path_str(path)
        rank = len(leaf.shape)
        stacked = "blocks/" in ps
        eff_rank = rank - 1 if stacked else rank
        spec = _match_spec(ps, eff_rank)
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


# ----------------------------------------------------------------------
# divisibility sanitizer — pjit INPUT shardings must divide dims exactly
# (uneven GSPMD padding is only legal for intermediates). Drop any axis
# assignment that does not divide its dimension (e.g. whisper's vocab
# 51865 over 16, GQA kv=2 heads over 16).
# ----------------------------------------------------------------------

def _n_shards(entry, axis_sizes) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for e in entry:
            n *= axis_sizes.get(e, 1)
        return n
    return axis_sizes.get(entry, 1)


def sanitize_specs(spec_tree, shape_tree, axis_sizes: dict):
    """Zero out per-dim assignments that don't divide the dim evenly."""

    def fix(spec, leaf):
        dims = leaf.shape
        entries = tuple(spec) + (None,) * (len(dims) - len(spec))
        out = []
        for dim, entry in zip(dims, entries):
            ns = _n_shards(entry, axis_sizes)
            out.append(entry if ns > 0 and dim % ns == 0 else None)
        return P(*out)

    return jax.tree.map(fix, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


# ----------------------------------------------------------------------
# batch / cache / opt-state specs
# ----------------------------------------------------------------------

def batch_axis(multi_pod: bool):
    return ("pod", D) if multi_pod else D


def train_batch_specs(cfg, multi_pod: bool = False):
    b = batch_axis(multi_pod)
    specs = {"tokens": P(b, None), "labels": P(b, None), "mask": P(b, None)}
    if cfg.num_prefix_tokens:
        specs["prefix_embeddings"] = P(b, None, None)
    if cfg.is_encdec:
        specs["encoder_frames"] = P(b, None, None)
    return specs


def _cache_leaf_spec(path: str, shape, *, batch_sharded: bool,
                     axis_sizes: dict):
    """Decode caches: leading num_groups dim, then batch. Shard batch over
    'data' when possible, otherwise the state/sequence dim. For KV caches,
    the kv-head dim goes to 'model' when it divides evenly; otherwise the
    *sequence* dim takes 'model' (GQA kv counts like 2, 4, 8 don't divide a
    16-way axis but 32k/500k sequences always do)."""
    rank = len(shape)
    bdim = D if batch_sharded else None
    nm = axis_sizes.get(M, 1)
    nd = axis_sizes.get(D, 1)
    if re.search(r"(^|/)(k|v)$", path) and rank == 5:     # (n,B,S,kv,hd)
        _, B, S, KV, _ = shape
        if KV % nm == 0:
            sdim = None if batch_sharded else (D if S % nd == 0 else None)
            return P(None, bdim, sdim, M, None)
        sq = M if S % nm == 0 else None
        return P(None, bdim, sq, None, None) if batch_sharded else \
            P(None, None, (D, M) if S % (nd * nm) == 0 else sq, None, None)
    if re.search(r"(k|v)_scale$", path) and rank == 4:    # (n,B,S,kv)
        _, B, S, KV = shape
        if KV % nm == 0:
            return P(None, bdim, None, M)
        return P(None, bdim, M if S % nm == 0 else None, None)
    if re.search(r"conv$", path) and rank == 4:           # (n,B,dc-1,di)
        return P(None, bdim, None, M)
    if re.search(r"ssm$", path) and rank == 4:            # (n,B,di,ds)
        return P(None, bdim, M, None)
    if re.search(r"C$", path) and rank == 5:              # (n,B,h,dk,dv)
        return P(None, bdim, None, None, M)
    if rank == 4:                                         # mlstm/slstm (n,B,h,dh)
        return P(None, bdim, None, M)
    if rank == 3:                                         # (n,B,h)
        return P(None, bdim, None)
    return P(*([None] * rank))


def decode_state_specs(cfg, state_shape, global_batch: int,
                       axis_sizes: dict):
    batch_sharded = global_batch % max(axis_sizes.get(D, 1), 1) == 0 \
        and global_batch >= axis_sizes.get(D, 1)

    def leaf_spec(path, leaf):
        return _cache_leaf_spec(_path_str(path), leaf.shape,
                                batch_sharded=batch_sharded,
                                axis_sizes=axis_sizes)

    return jax.tree_util.tree_map_with_path(leaf_spec, state_shape)


def decode_batch_specs(cfg, global_batch: int, multi_pod: bool = False):
    b = batch_axis(multi_pod)
    n = (2 if multi_pod else 1) * 16
    tok = P(b) if global_batch >= n else P(None)
    return {"tokens": tok}


def opt_state_specs(pspecs):
    """Optimizer state mirrors parameter sharding (momentum/adam moments)."""
    return pspecs


# ----------------------------------------------------------------------
# cohort runtime specs (repro.sim sharded backend)
# ----------------------------------------------------------------------
# Stage-3 local training is pure data parallelism over the cohort: the
# packed bucket tensors (xb, yb, step_mask, weights — all with a leading
# client axis) shard over 'data', the global params are replicated in, and
# the weighted FedAvg partial sum is psum-reduced across 'data' so the
# aggregate comes back replicated. The packer pads the client axis to a
# multiple of the mesh's data size (weight-0 rows), so the shard split is
# always even.

def cohort_param_spec():
    """Global params in / aggregated params out: replicated (P() is a valid
    pytree prefix for the whole param tree)."""
    return P()


def cohort_bucket_specs():
    """(xb, yb, step_mask, weights): client axis over 'data', everything
    else unsharded."""
    return (P(D), P(D), P(D), P(D))


def cohort_stacked_spec():
    """Per-client stacked outputs keep their leading client axis on
    'data'."""
    return P(D)


def fleet_class_specs():
    """Device-resident fleet path (repro.sim.fleet, ``--runtime device``):
    ``(class_x, class_y, rows, plans, step_mask, weights)``.  The class
    store tensors are replicated — every device gathers its own winners'
    rows out of the full store — while the per-invocation index/weight
    tensors shard their leading client axis over 'data' (the store pads
    ``client_cap`` to a multiple of the data-axis size)."""
    return (P(), P(), P(D), P(D), P(D), P(D))
