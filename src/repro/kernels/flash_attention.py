"""Pallas TPU kernel: blockwise (flash) attention with causal + sliding-
window masking.

TPU mapping (vs. the CUDA original): the online softmax keeps the running
(max, denom, acc) in VMEM scratch across the *innermost grid dimension* —
on TPU the grid is executed as a sequential loop per core, so the KV-block
axis is placed innermost and scratch persists across its iterations (the
TPU analogue of a warp-persistent accumulator). Q/K/V tiles are staged
HBM->VMEM by BlockSpec; matmul dims are MXU-aligned (block_q, block_k
multiples of 128, head_dim padded to 128).

Grid: (batch*heads, num_q_blocks, num_kv_blocks).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, block_q: int,
            block_k: int, sq: int, sk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < sk
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
        p, v_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _pad_axis(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    cfgp = [(0, 0)] * x.ndim
    cfgp[axis] = (0, pad)
    return jnp.pad(x, cfgp)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q, k, v: (B, S, H, hd) with kv already expanded to H heads (GQA is the
    caller's reshape). Returns (B, S, H, hd)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 8))
    scale = 1.0 / math.sqrt(hd)

    # (B,S,H,hd) -> (B*H, S, hd), pad S to block multiples
    def fold(t, s, b):
        t = t.transpose(0, 2, 1, 3).reshape(B * H, s, hd)
        return _pad_axis(t, b, 1)

    qf, kf, vf = fold(q, Sq, block_q), fold(k, Sk, block_k), fold(v, Sk, block_k)
    nq, nk = qf.shape[1] // block_q, kf.shape[1] // block_k
    grid = (B * H, nq, nk)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          block_q=block_q, block_k=block_k, sq=Sq, sk=Sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qf.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out[:, :Sq].reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
    return out
