"""Pure-jnp oracles for the Pallas kernels (per-kernel allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def kmeans_assign_ref(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """x: (N, F), c: (K, F) -> argmin_k ||x_n - c_k||^2, int32 (N,)."""
    d = (x[:, None, :].astype(jnp.float32)
         - c[None, :, :].astype(jnp.float32)) ** 2
    return jnp.argmin(d.sum(-1), axis=1).astype(jnp.int32)


def kmeans_min_dist_ref(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    d = ((x[:, None, :].astype(jnp.float32)
          - c[None, :, :].astype(jnp.float32)) ** 2).sum(-1)
    return d.min(axis=1)


def lloyd_step_ref(x: jnp.ndarray, c: jnp.ndarray):
    """Oracle for the fused Lloyd assign+update kernel. x: (N, F),
    c: (K, F) -> (labels (N,) int32, min_dist (N,) f32, sums (K, F) f32,
    counts (K,) f32) with sums[k] = sum of rows assigned to centroid k."""
    x32 = x.astype(jnp.float32)
    c32 = c.astype(jnp.float32)
    d = ((x32[:, None, :] - c32[None, :, :]) ** 2).sum(-1)
    lab = jnp.argmin(d, axis=1).astype(jnp.int32)
    onehot = jax.nn.one_hot(lab, c.shape[0], dtype=jnp.float32)
    return lab, d.min(axis=1), onehot.T @ x32, onehot.sum(0)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int = 0) -> jnp.ndarray:
    """q,k,v: (B, S, H, hd) (kv already expanded to H heads)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w,
                      v.astype(jnp.float32)).astype(q.dtype)
