"""jit'd public wrappers for the Pallas kernels with backend dispatch:
interpret mode on CPU (this container), compiled Pallas on real TPU,
pure-jnp reference as an always-available fallback.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as REF
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.kmeans import kmeans_assign as _kmeans_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def kmeans_assign(x, c, *, impl: str = "auto"):
    """Returns labels (N,) int32. impl: auto | pallas | ref."""
    if impl == "ref" or (impl == "auto" and x.shape[0] > 100_000
                         and not _on_tpu()):
        # interpret-mode pallas is slow for very large N on CPU
        return REF.kmeans_assign_ref(x, c)
    labels, _ = _kmeans_pallas(x, c, interpret=not _on_tpu())
    return labels


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    impl: str = "auto"):
    if impl == "ref":
        return REF.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         interpret=not _on_tpu())
