"""jit'd public wrappers for the Pallas kernels with backend dispatch:
interpret mode on CPU (this container), compiled Pallas on real TPU,
pure-jnp reference as an always-available fallback.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as REF
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.kmeans import kmeans_assign as _kmeans_pallas
from repro.kernels.kmeans import lloyd_step as _lloyd_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def kmeans_assign(x, c, *, impl: str = "auto"):
    """Returns labels (N,) int32. impl: auto | pallas | ref."""
    if impl == "ref" or (impl == "auto" and x.shape[0] > 100_000
                         and not _on_tpu()):
        # interpret-mode pallas is slow for very large N on CPU
        return REF.kmeans_assign_ref(x, c)
    labels, _ = _kmeans_pallas(x, c)   # interpret probed per backend
    return labels


def _lloyd_step_jnp(x, c):
    """Fused Lloyd step without Pallas: the same MXU-friendly matmul
    decomposition (||x||^2 - 2 x.c^T + ||c||^2 distances, one-hot^T @ x
    update) as XLA ops — the fast off-TPU path, and vmap/scan-safe."""
    x32 = x.astype(jnp.float32)
    c32 = c.astype(jnp.float32)
    d = ((x32 * x32).sum(1, keepdims=True) - 2.0 * (x32 @ c32.T)
         + (c32 * c32).sum(1)[None, :])
    lab = jnp.argmin(d, axis=1).astype(jnp.int32)
    onehot = jax.nn.one_hot(lab, c.shape[0], dtype=jnp.float32)
    return lab, d.min(axis=1), onehot.T @ x32, onehot.sum(0)


def lloyd_step(x, c, *, impl: str = "auto"):
    """One fused Lloyd assign+update pass. Returns (labels (N,) int32,
    min_dist (N,) f32, sums (K, F) f32, counts (K,) f32).

    impl: auto — compiled Pallas on TPU, fused jnp elsewhere (interpret
    mode pays a per-tile interpreter cost that defeats the fusion on CPU);
    pallas — force the kernel (interpret probed per backend); ref — the
    naive (N, K, F)-broadcast oracle."""
    if impl == "ref":
        return REF.lloyd_step_ref(x, c)
    if impl == "pallas" or (impl == "auto" and _on_tpu()):
        return _lloyd_pallas(x, c)     # interpret probed per backend
    return _lloyd_step_jnp(x, c)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    impl: str = "auto"):
    if impl == "ref":
        return REF.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         interpret=not _on_tpu())
