"""Pallas TPU kernel: k-means assignment (pairwise distance + argmin).

The paper's stage-1 clusters N clients by gradient features; at fleet scale
(N ~ 1e5-1e6 clients, F = 256-4096 features) the assignment step is the
compute hotspot of every Lloyd iteration. TPU mapping:

  * grid over blocks of N; each step loads an (BN, F) tile of features into
    VMEM (BlockSpec), with the full (K, F) centroid matrix resident (K is
    small: the paper uses J=10 clusters; padded to the 128-lane MXU width);
  * distances via the MXU:  ||x-c||^2 = ||x||^2 - 2 x·c^T + ||c||^2 — the
    x·c^T term is a (BN, F) @ (F, K) matmul, hardware-aligned when BN and K
    are multiples of (8, 128) and F of 128;
  * argmin + min-distance computed in-register, written per tile.

Validated in interpret mode against ref.kmeans_assign_ref (CPU container).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, c_ref, cn_ref, lab_ref, dist_ref, *, k_real: int):
    x = x_ref[...].astype(jnp.float32)            # (BN, F)
    c = c_ref[...].astype(jnp.float32)            # (Kp, F)
    cn = cn_ref[...]                              # (1, Kp) ||c||^2 (padded=+inf)
    prod = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)       # (BN, Kp) on the MXU
    xn = jnp.sum(x * x, axis=1, keepdims=True)    # (BN, 1)
    d = xn - 2.0 * prod + cn                      # (BN, Kp)
    kp = d.shape[1]
    col = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    d = jnp.where(col < k_real, d, jnp.inf)
    lab_ref[...] = jnp.argmin(d, axis=1).astype(jnp.int32)
    dist_ref[...] = jnp.min(d, axis=1)


def _pad_to(x, m, axis, value=0.0):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    cfgp = [(0, 0)] * x.ndim
    cfgp[axis] = (0, pad)
    return jnp.pad(x, cfgp, constant_values=value)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign(x: jnp.ndarray, c: jnp.ndarray, *, block_n: int = 128,
                  interpret: bool = True):
    """x: (N, F), c: (K, F) -> (labels (N,) int32, min_dist (N,) f32)."""
    n, f = x.shape
    k = c.shape[0]
    xp = _pad_to(_pad_to(x, block_n, 0), 128, 1)
    cp = _pad_to(_pad_to(c, 128, 0), 128, 1)
    cn = jnp.sum(cp.astype(jnp.float32) ** 2, axis=1)[None, :]  # (1, Kp)
    kp = cp.shape[0]
    npad, fp = xp.shape
    grid = (npad // block_n,)

    labels, dists = pl.pallas_call(
        functools.partial(_kernel, k_real=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, fp), lambda i: (i, 0)),   # feature tile
            pl.BlockSpec((kp, fp), lambda i: (0, 0)),        # centroids resident
            pl.BlockSpec((1, kp), lambda i: (0, 0)),         # ||c||^2
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad,), jnp.int32),
            jax.ShapeDtypeStruct((npad,), jnp.float32),
        ],
        interpret=interpret,
    )(xp, cp, cn)
    return labels[:n], dists[:n]
