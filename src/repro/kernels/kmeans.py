"""Pallas TPU kernels for fleet-scale k-means (stage-1 clustering).

The paper's stage-1 clusters N clients by gradient features; at fleet scale
(N ~ 1e5-1e6 clients, F = 256-4096 features) every Lloyd iteration is the
compute hotspot. Two kernels:

  * :func:`kmeans_assign` — assignment only (pairwise distance + argmin).
  * :func:`lloyd_step`    — the fused assign+update step: one grid pass
    over N emits labels and min-distances per tile AND accumulates the
    per-centroid partial sums / counts, so a full Lloyd iteration needs no
    separate (N, K) one-hot matmul over a second pass of the features.

TPU mapping (both kernels):

  * grid over blocks of N; each step loads an (BN, F) tile of features into
    VMEM (BlockSpec), with the full (K, F) centroid matrix resident (K is
    small: the paper uses J=10 clusters; padded to the 128-lane MXU width);
  * distances via the MXU:  ||x-c||^2 = ||x||^2 - 2 x·c^T + ||c||^2 — the
    x·c^T term is a (BN, F) @ (F, K) matmul, hardware-aligned when BN and K
    are multiples of (8, 128) and F of 128;
  * argmin + min-distance computed in-register, written per tile;
  * (lloyd_step) the tile's one-hot^T @ x partial sums and counts are
    accumulated into a (K, F) / (1, K) output block that every grid step
    maps to — zeroed at step 0, so the sequential TPU grid acts as the
    reduction loop.

``interpret=None`` (the default) probes the backend: compiled on TPU,
interpret mode elsewhere. Validated in interpret mode against
ref.kmeans_assign_ref / ref.lloyd_step_ref (CPU container).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _resolve_interpret(interpret):
    """Backend probe: compiled Pallas on TPU, interpreter elsewhere."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _distances(x, c, cn, k_real):
    """(BN, Kp) squared distances with padded centroid columns = +inf."""
    prod = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)       # (BN, Kp) on the MXU
    xn = jnp.sum(x * x, axis=1, keepdims=True)    # (BN, 1)
    d = xn - 2.0 * prod + cn                      # (BN, Kp)
    col = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    return jnp.where(col < k_real, d, jnp.inf), col


def _assign_kernel(x_ref, c_ref, cn_ref, lab_ref, dist_ref, *, k_real: int):
    x = x_ref[...].astype(jnp.float32)            # (BN, F)
    c = c_ref[...].astype(jnp.float32)            # (Kp, F)
    cn = cn_ref[...]                              # (1, Kp) ||c||^2
    d, _ = _distances(x, c, cn, k_real)
    lab_ref[...] = jnp.argmin(d, axis=1).astype(jnp.int32)
    dist_ref[...] = jnp.min(d, axis=1)


def _lloyd_kernel(x_ref, c_ref, cn_ref, lab_ref, dist_ref, sum_ref, cnt_ref,
                  *, k_real: int, n_real: int, block_n: int):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)            # (BN, F)
    c = c_ref[...].astype(jnp.float32)            # (Kp, F)
    cn = cn_ref[...]                              # (1, Kp) ||c||^2
    d, col = _distances(x, c, cn, k_real)
    lab = jnp.argmin(d, axis=1).astype(jnp.int32)         # (BN,)
    row = jax.lax.broadcasted_iota(jnp.int32, d.shape, 0) # (BN, Kp)
    valid = row + i * block_n < n_real            # padded rows masked out
    lab_ref[...] = lab
    dist_ref[...] = jnp.where(valid[:, 0], jnp.min(d, axis=1), 0.0)
    onehot = ((col == lab[:, None]) & valid).astype(jnp.float32)  # (BN, Kp)
    # partial assign+update: every grid step maps to the same (Kp, F) /
    # (1, Kp) output block, so += across the sequential grid reduces N
    @pl.when(i == 0)
    def _():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
    sum_ref[...] += jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)       # (Kp, F) = onehot^T @ x
    cnt_ref[...] += jnp.sum(onehot, axis=0, keepdims=True)


def _pad_to(x, m, axis, value=0.0):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    cfgp = [(0, 0)] * x.ndim
    cfgp[axis] = (0, pad)
    return jnp.pad(x, cfgp, constant_values=value)


def _padded(x, c, block_n):
    xp = _pad_to(_pad_to(x, block_n, 0), 128, 1)
    cp = _pad_to(_pad_to(c, 128, 0), 128, 1)
    cn = jnp.sum(cp.astype(jnp.float32) ** 2, axis=1)[None, :]  # (1, Kp)
    return xp, cp, cn


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign(x: jnp.ndarray, c: jnp.ndarray, *, block_n: int = 128,
                  interpret: bool | None = None):
    """x: (N, F), c: (K, F) -> (labels (N,) int32, min_dist (N,) f32).

    ``interpret=None`` probes the backend (compiled on TPU only)."""
    interpret = _resolve_interpret(interpret)
    n, f = x.shape
    k = c.shape[0]
    xp, cp, cn = _padded(x, c, block_n)
    kp = cp.shape[0]
    npad, fp = xp.shape
    grid = (npad // block_n,)

    labels, dists = pl.pallas_call(
        functools.partial(_assign_kernel, k_real=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, fp), lambda i: (i, 0)),   # feature tile
            pl.BlockSpec((kp, fp), lambda i: (0, 0)),        # centroids resident
            pl.BlockSpec((1, kp), lambda i: (0, 0)),         # ||c||^2
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad,), jnp.int32),
            jax.ShapeDtypeStruct((npad,), jnp.float32),
        ],
        interpret=interpret,
    )(xp, cp, cn)
    return labels[:n], dists[:n]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lloyd_step(x: jnp.ndarray, c: jnp.ndarray, *, block_n: int = 128,
               interpret: bool | None = None):
    """Fused Lloyd assign+update. x: (N, F), c: (K, F) ->
    (labels (N,) int32, min_dist (N,) f32, sums (K, F) f32, counts (K,) f32)
    where sums[k] = sum of features assigned to k and counts[k] their count
    — one grid pass over N, no second (N, K) one-hot matmul."""
    interpret = _resolve_interpret(interpret)
    n, f = x.shape
    k = c.shape[0]
    xp, cp, cn = _padded(x, c, block_n)
    kp = cp.shape[0]
    npad, fp = xp.shape
    grid = (npad // block_n,)

    labels, dists, sums, counts = pl.pallas_call(
        functools.partial(_lloyd_kernel, k_real=k, n_real=n,
                          block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, fp), lambda i: (i, 0)),   # feature tile
            pl.BlockSpec((kp, fp), lambda i: (0, 0)),        # centroids resident
            pl.BlockSpec((1, kp), lambda i: (0, 0)),         # ||c||^2
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((kp, fp), lambda i: (0, 0)),        # accumulators
            pl.BlockSpec((1, kp), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad,), jnp.int32),
            jax.ShapeDtypeStruct((npad,), jnp.float32),
            jax.ShapeDtypeStruct((kp, fp), jnp.float32),
            jax.ShapeDtypeStruct((1, kp), jnp.float32),
        ],
        interpret=interpret,
    )(xp, cp, cn)
    return labels[:n], dists[:n], sums[:k, :f], counts[0, :k]
