"""Synthetic datasets (the container is offline — no MNIST download).

Class-conditional image distributions with the same tensor shapes as the
paper's datasets:

  * 'mnist'  : (28, 28, 1), 10 classes, 60k train / 10k test
  * 'fmnist' : (28, 28, 1), 10 classes
  * 'cifar'  : (32, 32, 3), 10 classes, 50k train / 10k test

Each class c has a smooth random template (low-frequency pattern upsampled
from an 7x7 seed); samples are template + per-sample affine jitter + pixel
noise. A small CNN separates the classes but needs real training signal, so
convergence-rate comparisons between selection schemes remain meaningful —
the paper's claims are about *relative* convergence under heterogeneity,
which this preserves.

Also provides topic-conditional token data for LLM-scale FL examples.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Dataset:
    x: np.ndarray          # (N, H, W, C) float32 in [0, 1]
    y: np.ndarray          # (N,) int32
    num_classes: int


def _templates(key, num_classes: int, hw: Tuple[int, int, int]):
    h, w, c = hw
    seeds = jax.random.normal(key, (num_classes, 7, 7, c))
    t = jax.image.resize(seeds, (num_classes, h, w, c), "bilinear")
    return 0.5 + 0.35 * t / jnp.maximum(jnp.abs(t).max(), 1e-6)


# fixed name->seed offsets: Python's hash(name) varies per process under
# hash randomization (PYTHONHASHSEED), which made datasets nondeterministic
# across runs; unknown names fall back to a stable digest
_NAME_SEEDS = {"mnist": 11, "fmnist": 22_222, "cifar": 44_444}


def _name_seed(name: str) -> int:
    if name in _NAME_SEEDS:
        return _NAME_SEEDS[name]
    import zlib
    return zlib.crc32(name.encode()) % 65536


def make_image_dataset(name: str, n_train: int = 12_000, n_test: int = 2_000,
                       noise: float = 0.12, seed: int = 0) -> Tuple[Dataset, Dataset]:
    hw = (32, 32, 3) if name == "cifar" else (28, 28, 1)
    nc = 10
    key = jax.random.PRNGKey(seed + _name_seed(name))
    kt, kn1, kn2, ks1, ks2 = jax.random.split(key, 5)
    temps = _templates(kt, nc, hw)

    def gen(k, n):
        ky, kshift, knoise = jax.random.split(k, 3)
        y = jax.random.randint(ky, (n,), 0, nc)
        base = temps[y]
        # per-sample roll (translation jitter)
        sh = jax.random.randint(kshift, (n, 2), -2, 3)
        def roll_one(img, s):
            return jnp.roll(jnp.roll(img, s[0], axis=0), s[1], axis=1)
        base = jax.vmap(roll_one)(base, sh)
        x = base + noise * jax.random.normal(knoise, base.shape)
        return np.asarray(jnp.clip(x, 0.0, 1.0), np.float32), \
            np.asarray(y, np.int32)

    xtr, ytr = gen(jax.random.fold_in(kn1, 0), n_train)
    xte, yte = gen(jax.random.fold_in(kn2, 1), n_test)
    return Dataset(xtr, ytr, nc), Dataset(xte, yte, nc)


def make_token_dataset(num_topics: int = 10, vocab: int = 256,
                       seq_len: int = 64, n: int = 4_000, seed: int = 0):
    """Topic-conditional token sequences (for transformer FL examples):
    each topic is a Zipf distribution over a topic-specific permutation of
    the vocabulary; 'labels' = topic ids (the non-IID partition key)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1)
    zipf = (1.0 / ranks) / (1.0 / ranks).sum()
    perms = np.stack([rng.permutation(vocab) for _ in range(num_topics)])
    topics = rng.integers(0, num_topics, n)
    toks = np.empty((n, seq_len), np.int32)
    for t in range(num_topics):
        m = topics == t
        draw = rng.choice(vocab, size=(int(m.sum()), seq_len), p=zipf)
        toks[m] = perms[t][draw]
    return toks, topics.astype(np.int32)
