"""Non-IID + imbalanced federated partitioning (paper §V-A).

  * Non-IID level nu: a fraction nu of each client's samples carries the
    client's primary label; the remainder is drawn uniformly from the global
    pool. nu in {1, 0.8, 0.5} in the paper's experiments.
  * Imbalance: the local size of client i is uniform in
    [varpi * imbalance_low, varpi * imbalance_high] where varpi is the
    per-client average (paper: [varpi/6, 2*varpi], e.g. 100..1200 for 100
    clients on MNIST).
  * Per-client split 80/10/10 train/val/test.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from repro.configs.base import FLConfig


@dataclass
class ClientData:
    """Index-based view into the global pool."""

    train_idx: np.ndarray
    val_idx: np.ndarray
    test_idx: np.ndarray
    primary_label: int

    @property
    def size(self) -> int:
        return len(self.train_idx)


def partition_clients(y: np.ndarray, cfg: FLConfig,
                      seed: int = 0) -> List[ClientData]:
    """Partition a global pool with labels y into cfg.num_clients clients."""
    rng = np.random.default_rng(seed)
    n_global = len(y)
    nc = cfg.num_classes
    by_label = [np.nonzero(y == c)[0] for c in range(nc)]
    varpi = n_global // cfg.num_clients

    lo = max(int(varpi * cfg.imbalance_low), 10)
    hi = max(int(varpi * cfg.imbalance_high), lo + 1)

    clients = []
    for i in range(cfg.num_clients):
        primary = i % nc
        size = int(rng.integers(lo, hi + 1))
        n_primary = int(round(cfg.non_iid_level * size))
        idx_p = rng.choice(by_label[primary], n_primary,
                           replace=len(by_label[primary]) < n_primary)
        idx_r = rng.choice(n_global, size - n_primary, replace=False) \
            if size > n_primary else np.empty((0,), np.int64)
        idx = np.concatenate([idx_p, idx_r])
        rng.shuffle(idx)
        n_tr = int(0.8 * size)
        n_va = int(0.1 * size)
        clients.append(ClientData(
            train_idx=idx[:n_tr],
            val_idx=idx[n_tr:n_tr + n_va],
            test_idx=idx[n_tr + n_va:],
            primary_label=primary,
        ))
    return clients


def client_label_histograms(y: np.ndarray, clients: List[ClientData],
                            num_classes: int) -> np.ndarray:
    h = np.zeros((len(clients), num_classes))
    for i, c in enumerate(clients):
        lab, cnt = np.unique(y[c.train_idx], return_counts=True)
        h[i, lab] = cnt
        h[i] /= max(h[i].sum(), 1)
    return h


def global_histogram(y: np.ndarray, num_classes: int) -> np.ndarray:
    h = np.bincount(y, minlength=num_classes).astype(np.float64)
    return h / h.sum()
