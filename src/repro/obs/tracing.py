"""Lightweight span tracing for the round pipeline.

``span("round/dispatch", round=t)`` is a context manager that records a
``{"kind": "span", name, id, parent, depth, t0, dur_s, **meta}`` event on
exit.  Spans nest through a thread-local stack (each thread traces its
own tree), use the monotonic clock (registry epoch), and are safe to
leave in hot paths: with no sink attached ``span()`` returns a shared
null context manager (one branch + one attribute load per call), and
when enabled the cost is two ``perf_counter`` reads plus one buffered
dict append at exit — no I/O, no device sync.

The async server records *dispatch* spans (``round/dispatch`` and its
children) separately from *drain* spans (``round/drain``): a dispatch
span measures only the host time to enqueue the round's work, so the
pipeline's device/host overlap shows up as dispatch spans much shorter
than the wall time between drains instead of being averaged away.
"""
from __future__ import annotations

import itertools
import threading

from repro.obs.registry import OBS, now

_ids = itertools.count(1)
_tls = threading.local()


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


class _Span:
    __slots__ = ("name", "meta", "t0", "sid", "parent")

    def __init__(self, name, meta):
        self.name = name
        self.meta = meta

    def __enter__(self):
        stack = getattr(_tls, "stack", None)
        if stack is None:
            stack = _tls.stack = []
        self.parent = stack[-1].sid if stack else None
        self.sid = next(_ids)
        stack.append(self)
        self.t0 = now()
        return self

    def __exit__(self, *exc):
        t1 = now()
        stack = _tls.stack
        depth = len(stack) - 1
        if stack and stack[-1] is self:
            stack.pop()
        OBS.event("span", name=self.name, id=self.sid, parent=self.parent,
                  depth=depth, t0=round(self.t0, 6),
                  dur_s=round(t1 - self.t0, 6), **self.meta)
        return False


_RESERVED = frozenset(("kind", "ts", "name", "id", "parent", "depth",
                       "t0", "dur_s"))


def span(name: str, **meta):
    """Open a span; a no-op shared context manager while obs is
    disabled.  ``meta`` must be JSON-serializable host scalars; keys
    clashing with the span schema fields are prefixed ``meta_``."""
    if not OBS.enabled:
        return _NULL
    if _RESERVED & meta.keys():
        meta = {(f"meta_{k}" if k in _RESERVED else k): v
                for k, v in meta.items()}
    return _Span(name, meta)
