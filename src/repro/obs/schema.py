"""Event-stream schema + validator for the obs JSONL log.

The schema is deliberately small: every event is one flat JSON object
with a ``kind`` and a monotonic ``ts``; per-kind required fields are
listed in :data:`REQUIRED`.  :func:`validate_events` checks structural
validity plus the three pipeline invariants the CI smoke step cares
about (see .github/workflows/ci.yml):

  * **every round present** — with ``rounds=T``, exactly one ``round``
    series event and one ``round/dispatch`` span per round in [0, T);
  * **spans nested correctly** — unique ids, non-negative durations,
    each child's [t0, t0+dur] inside its parent's window, child depth =
    parent depth + 1;
  * **eval cadence respected** — with ``eval_every=k``, ``test_acc`` /
    ``test_loss`` are numbers exactly on due rounds (multiples of k and
    the final round) and null on skipped ones (NaN sanitizes to null in
    the file sinks).

With ``scheme_select`` (the run's control-plane selection scheme,
repro.core.schemes) the validator additionally checks the scheme-tagged
scalar series: every round row must carry a numeric
``fairness_hist_std`` (all schemes emit it), and scheme_state-bearing
schemes (:data:`STATEFUL_SCHEMES`) must log their budget ledger
(``budget_spent`` / ``budget_remaining``) every round — a stateful
scheme whose budget scalars are missing is a broken metrics drain, not
a valid stream.

CLI (used by CI):

    python -m repro.obs.schema events.jsonl --rounds 6 --eval-every 2 \
        --scheme-select longterm_auction
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional

KINDS = ("meta", "round", "span", "counter", "gauge", "jax_stats", "log",
         "dynamics", "defense", "watchdog")

REQUIRED: Dict[str, tuple] = {
    "round": ("round", "test_acc", "test_loss", "energy_std", "mean_bid",
              "vds_gap"),
    "span": ("name", "id", "parent", "depth", "t0", "dur_s"),
    "counter": ("name", "value"),
    "gauge": ("name", "value"),
    "log": ("msg",),
    # fleet-dynamics events (round/empty, buffer/fold) — see
    # repro.core.server and DESIGN.md §Fleet dynamics
    "dynamics": ("name",),
    # defended-aggregation events (quarantine, band_screen,
    # round/diverged) — see repro.core.aggregation and DESIGN.md
    # §Threat model
    "defense": ("name",),
    # divergence-watchdog events: a ``rollback`` event additionally
    # carries round / restored_round / reason (checked below — the
    # self-healing CI smoke asserts at least one)
    "watchdog": ("name",),
}

_EPS = 5e-3   # span clock tolerance (perf_counter rounding at 1e-6 + loop)

# schemes that thread a scheme_state pytree and therefore MUST log their
# budget scalars every round.  A literal, not an import: this module
# deliberately has no jax dependency (it validates logs anywhere), so
# the registry can't be consulted here — tests/test_schemes.py asserts
# this tuple equals repro.core.schemes.stateful_scheme_names().
STATEFUL_SCHEMES = ("longterm_auction",)

# scalar series every scheme-tagged stream must carry per round row
_SCHEME_SCALARS = ("fairness_hist_std",)
# …plus these for STATEFUL_SCHEMES (the carried budget ledger)
_BUDGET_SCALARS = ("budget_spent", "budget_remaining")


def _is_num(v: Any) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate_events(events: List[Dict[str, Any]],
                    rounds: Optional[int] = None,
                    eval_every: Optional[int] = None,
                    scheme_select: Optional[str] = None,
                    reputation_mode: Optional[str] = None,
                    min_rollbacks: Optional[int] = None) -> List[str]:
    """Return a list of human-readable schema violations (empty = valid).

    ``reputation_mode="price"`` additionally requires every round row to
    carry the numeric trust-score scalars (``trust_mean`` /
    ``trust_min`` in (0, 1]); ``min_rollbacks=n`` requires at least n
    well-formed ``watchdog`` rollback events (the self-healing smoke's
    assertion that the watchdog actually fired)."""
    errs: List[str] = []
    spans: Dict[int, Dict[str, Any]] = {}
    round_rows: Dict[int, Dict[str, Any]] = {}
    dispatch_rounds: List[int] = []
    n_drains = 0
    n_rollbacks = 0

    for i, e in enumerate(events):
        if not isinstance(e, dict):
            errs.append(f"event {i}: not an object")
            continue
        kind = e.get("kind")
        if kind not in KINDS:
            errs.append(f"event {i}: unknown kind {kind!r}")
            continue
        if not _is_num(e.get("ts")) or e["ts"] < 0:
            errs.append(f"event {i} ({kind}): bad ts {e.get('ts')!r}")
        for f in REQUIRED.get(kind, ()):
            if f not in e:
                errs.append(f"event {i} ({kind}): missing field {f!r}")
        if kind == "watchdog" and e.get("name") == "rollback":
            ok_rb = True
            for f in ("round", "restored_round"):
                if not _is_num(e.get(f)):
                    errs.append(f"event {i} (watchdog rollback): "
                                f"non-numeric {f!r}: {e.get(f)!r}")
                    ok_rb = False
            if not isinstance(e.get("reason"), str):
                errs.append(f"event {i} (watchdog rollback): missing "
                            f"string 'reason', got {e.get('reason')!r}")
                ok_rb = False
            if ok_rb:
                n_rollbacks += 1
        if kind == "round" and _is_num(e.get("round")):
            r = int(e["round"])
            if r in round_rows:
                errs.append(f"round {r}: duplicate series row")
            round_rows[r] = e
        if kind == "span" and _is_num(e.get("id")):
            sid = int(e["id"])
            if sid in spans:
                errs.append(f"span id {sid}: duplicate")
            spans[sid] = e
            if e.get("name") == "round/dispatch":
                dispatch_rounds.append(int(e.get("round", -1)))
            if e.get("name") == "round/drain":
                n_drains += 1

    # span nesting
    for sid, s in spans.items():
        if not (_is_num(s.get("dur_s")) and s["dur_s"] >= 0):
            errs.append(f"span {s.get('name')} ({sid}): bad dur_s "
                        f"{s.get('dur_s')!r}")
            continue
        parent = s.get("parent")
        if parent is None:
            if s.get("depth") != 0:
                errs.append(f"span {s.get('name')} ({sid}): no parent but "
                            f"depth {s.get('depth')}")
            continue
        p = spans.get(int(parent))
        if p is None:
            errs.append(f"span {s.get('name')} ({sid}): parent {parent} "
                        "not in stream")
            continue
        if s.get("depth") != p.get("depth", -2) + 1:
            errs.append(f"span {s.get('name')} ({sid}): depth "
                        f"{s.get('depth')} under parent depth "
                        f"{p.get('depth')}")
        if s["t0"] < p["t0"] - _EPS or \
                s["t0"] + s["dur_s"] > p["t0"] + p["dur_s"] + _EPS:
            errs.append(f"span {s.get('name')} ({sid}): window "
                        f"[{s['t0']}, {s['t0'] + s['dur_s']}] escapes "
                        f"parent {p.get('name')} "
                        f"[{p['t0']}, {p['t0'] + p['dur_s']}]")

    # every round present
    if rounds is not None:
        want = set(range(int(rounds)))
        got = set(round_rows)
        if got != want:
            errs.append(f"round series: missing {sorted(want - got)}, "
                        f"unexpected {sorted(got - want)}")
        missing_d = want - set(dispatch_rounds)
        if missing_d:
            errs.append("round/dispatch spans missing for rounds "
                        f"{sorted(missing_d)}")
        if n_drains == 0:
            errs.append("no round/drain span in stream")

    # eval cadence (file sinks sanitize NaN -> null; the in-memory sink
    # keeps the raw float — both spell "no eval this round").  Rows that
    # carry the explicit ``eval_skipped`` flag are checked against it
    # directly: a null/NaN acc with eval_skipped=false is a DIVERGED
    # eval (the eval ran and came back non-finite), which is legal here
    # — the inference "null means skipped" only holds for older logs
    # that predate the flag.
    if rounds is not None and eval_every is not None:
        for r, e in sorted(round_rows.items()):
            due = eval_every <= 1 or r % eval_every == 0 \
                or r == int(rounds) - 1
            acc = e.get("test_acc")
            null_acc = acc is None or (isinstance(acc, float) and acc != acc)
            if "eval_skipped" in e:
                skipped = bool(e["eval_skipped"])
                if skipped and not null_acc:
                    errs.append(f"round {r}: eval_skipped but "
                                f"test_acc={acc!r}")
                if due and skipped:
                    errs.append(f"round {r}: eval due but skipped")
            else:
                skipped = null_acc
                if due and (skipped or not _is_num(acc)):
                    errs.append(f"round {r}: eval due but test_acc={acc!r}")
            if not due and not skipped:
                errs.append(f"round {r}: eval off-cadence but "
                            f"test_acc={acc!r} (expected null)")

    # scheme-tagged scalar series (see module docstring)
    if scheme_select is not None:
        want = _SCHEME_SCALARS + (
            _BUDGET_SCALARS if scheme_select in STATEFUL_SCHEMES else ())
        for r, e in sorted(round_rows.items()):
            for f in want:
                if not _is_num(e.get(f)):
                    errs.append(
                        f"round {r}: scheme {scheme_select!r} requires "
                        f"numeric {f!r}, got {e.get(f)!r}")

    # reputation-pricing scalar series: the continuous trust score must
    # be logged every round, and it lives in (0, 1] by construction
    if reputation_mode == "price":
        for r, e in sorted(round_rows.items()):
            for f in ("trust_mean", "trust_min"):
                v = e.get(f)
                if not _is_num(v):
                    errs.append(f"round {r}: reputation_mode='price' "
                                f"requires numeric {f!r}, got {v!r}")
                elif not 0.0 < v <= 1.0:
                    errs.append(f"round {r}: {f}={v!r} outside (0, 1]")

    # watchdog rollback floor (self-healing smoke)
    if min_rollbacks is not None and n_rollbacks < int(min_rollbacks):
        errs.append(f"watchdog: {n_rollbacks} well-formed rollback "
                    f"event(s), expected >= {min_rollbacks}")
    return errs


def load_jsonl(path: str) -> List[Dict[str, Any]]:
    events = []
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{ln}: invalid JSON: {e}") from e
    return events


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Validate an obs JSONL event log against the schema.")
    ap.add_argument("path")
    ap.add_argument("--rounds", type=int, default=None,
                    help="assert one round row + dispatch span per round "
                         "in [0, N)")
    ap.add_argument("--eval-every", type=int, default=None,
                    help="assert the eval NaN/number cadence")
    ap.add_argument("--scheme-select", default=None,
                    help="assert the scheme-tagged scalar series: every "
                         "round row carries fairness_hist_std, and "
                         "stateful schemes (longterm_auction) their "
                         "budget_spent/budget_remaining ledger")
    ap.add_argument("--reputation-mode", default=None,
                    help="'price' asserts every round row carries the "
                         "numeric trust_mean/trust_min scalars in (0, 1]")
    ap.add_argument("--min-rollbacks", type=int, default=None,
                    help="assert at least N well-formed watchdog "
                         "rollback events")
    args = ap.parse_args()
    events = load_jsonl(args.path)
    errs = validate_events(events, rounds=args.rounds,
                           eval_every=args.eval_every,
                           scheme_select=args.scheme_select,
                           reputation_mode=args.reputation_mode,
                           min_rollbacks=args.min_rollbacks)
    if errs:
        for e in errs:
            print(f"SCHEMA: {e}", file=sys.stderr)
        sys.exit(1)
    n_spans = sum(e.get("kind") == "span" for e in events)
    n_rounds = sum(e.get("kind") == "round" for e in events)
    print(f"{args.path}: {len(events)} events ok "
          f"({n_rounds} round rows, {n_spans} spans)")


if __name__ == "__main__":
    main()
