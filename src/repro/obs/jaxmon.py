"""JAX-awareness layer: centralized retrace/compile counters, device
transfer accounting, opt-in profiler capture, and the transfer-guard
sync auditor.

``jax_stats`` generalizes the per-engine ``CohortEngine.stats`` counters
into one process-wide tally: traced bodies call
``jax_stats.note_trace(what)`` (a Python side effect, so it fires at
trace/compile time ONLY — counting adds literally nothing to the warm
path), shape-cache bookkeeping calls ``note_shape``, and the
:func:`device_put` / :func:`device_get` wrappers count explicit host
transfers by direction, bytes and calls.  Tests and benchmarks snapshot
the counters around a warm window to assert "zero retraces" and "no
hidden transfers" (tests/test_obs.py, tests/test_fleet.py).

The **sync auditor** (:func:`sync_audit`) wraps a code region in jax's
transfer guards for both host directions set to ``disallow``: any
*implicit* host<->device transfer (a numpy array silently fed to a
jitted program, a ``float()`` on a device scalar) raises, while explicit
``jax.device_put`` / ``jax.device_get`` — the transfers the async
pipeline performs on purpose, all routed through the counted wrappers —
stay legal.  Device-to-device transfers are left unguarded: resharding
committed arrays onto a mesh is exactly what the sharded paths are
supposed to do.  CPU caveat: on the CPU backend "device" buffers live in
host RAM, so the guard audits *API-level* sync discipline (which is what
retrace/dispatch stalls care about), not physical PCIe traffic — see
DESIGN.md §Observability.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict

import jax

from repro.obs.registry import OBS


class JaxStats:
    """Process-wide retrace / transfer counters (thread-safe)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counters: Dict[str, int] = {}
        self._last_emitted: Dict[str, int] = {}

    def _inc(self, name: str, by: int = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + by

    def note_trace(self, what: str = "jit") -> None:
        """Call from inside a traced body: runs at (re)trace time only."""
        self._inc("traces")
        self._inc(f"traces/{what}")

    def note_shape(self, hit: bool) -> None:
        self._inc("shape_hits" if hit else "shape_misses")

    def note_transfer(self, direction: str, nbytes: int,
                      calls: int = 1) -> None:
        """``direction`` is 'h2d' or 'd2h' (explicit, counted wrappers)."""
        self._inc(f"{direction}_bytes", nbytes)
        self._inc(f"{direction}_calls", calls)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.counters)

    def delta(self, since: Dict[str, int]) -> Dict[str, int]:
        """Counter movement since a :meth:`snapshot` (only nonzero keys)."""
        snap = self.snapshot()
        keys = set(snap) | set(since)
        return {k: snap.get(k, 0) - since.get(k, 0) for k in keys
                if snap.get(k, 0) != since.get(k, 0)}

    def reset(self) -> None:
        with self._lock:
            self.counters.clear()
            self._last_emitted.clear()


jax_stats = JaxStats()


def _emit_jax_stats() -> None:
    """Flush hook: one ``jax_stats`` event per flush iff counters moved."""
    snap = jax_stats.snapshot()
    if snap and snap != jax_stats._last_emitted:
        jax_stats._last_emitted = snap
        OBS.event("jax_stats", **snap)


OBS.add_flush_hook(_emit_jax_stats)


def _tree_nbytes(tree: Any) -> int:
    return sum(getattr(x, "nbytes", 0) for x in jax.tree.leaves(tree))


def device_put(tree: Any, *args, **kwargs):
    """Counted explicit host->device transfer (pytree-aware).  Using this
    instead of feeding numpy straight into a jitted call is what makes
    the round loop's intended transfers *explicit* — and therefore legal
    under :func:`sync_audit` — while keeping the byte/count books."""
    jax_stats.note_transfer("h2d", _tree_nbytes(tree))
    return jax.device_put(tree, *args, **kwargs)


def device_get(tree: Any):
    """Counted explicit device->host transfer (pytree-aware).  Bytes are
    tallied from the fetched host buffers, so the count itself never adds
    a device sync."""
    out = jax.device_get(tree)
    jax_stats.note_transfer("d2h", _tree_nbytes(out))
    return out


@contextlib.contextmanager
def sync_audit(mode: str = "disallow"):
    """Assert a region performs no *implicit* host transfers (both
    directions guarded; device-to-device left alone — see module
    docstring).  Wrap warm round dispatches:

        with obs.sync_audit():
            server._dispatch_round(t, eval_now)

    Raises jax's XlaRuntimeError at the offending transfer."""
    with jax.transfer_guard_host_to_device(mode), \
            jax.transfer_guard_device_to_host(mode):
        yield


@contextlib.contextmanager
def maybe_profile(profile_dir):
    """Opt-in ``jax.profiler`` trace capture (``--profile-dir``): a
    no-op when ``profile_dir`` is falsy, otherwise the whole region is
    captured for TensorBoard/Perfetto."""
    if not profile_dir:
        yield
        return
    with jax.profiler.trace(str(profile_dir)):
        yield
