"""Event sinks for the metrics registry (repro.obs.registry).

All sinks consume batches of event dicts at flush time; none are touched
from the hot path.  File sinks sanitize non-finite floats to ``null`` so
every line/row stays strictly-valid JSON/CSV (NaN is how the server logs
off-cadence eval rounds — see FederatedServer.run)."""
from __future__ import annotations

import json
import math
from typing import Any, Dict, List


def _sanitize(v: Any) -> Any:
    """Strict-JSON scalar: non-finite floats become None."""
    if isinstance(v, float) and not math.isfinite(v):
        return None
    return v


def sanitize_event(e: Dict[str, Any]) -> Dict[str, Any]:
    return {k: _sanitize(v) for k, v in e.items()}


class MemorySink:
    """In-memory sink for tests: ``events`` is the raw (unsanitized)
    event list in emission order."""

    def __init__(self):
        self.events: List[Dict[str, Any]] = []

    def emit(self, batch: List[Dict[str, Any]]) -> None:
        self.events.extend(batch)

    def close(self) -> None:
        pass


class JsonlSink:
    """One strict-JSON object per line (``--log-jsonl``).  The file is
    line-buffered only at flush boundaries: a flush writes its whole
    batch then fsync-free flushes the Python buffer, so a crashed run
    keeps every completed logging boundary."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "w")

    def emit(self, batch: List[Dict[str, Any]]) -> None:
        for e in batch:
            self._f.write(json.dumps(sanitize_event(e), sort_keys=False))
            self._f.write("\n")
        self._f.flush()

    def close(self) -> None:
        self._f.close()


class CsvSink:
    """Flat CSV (``--log-csv``): fixed columns for the common fields,
    everything else JSON-packed into ``extra`` so no event loses data."""

    COLUMNS = ("kind", "ts", "name", "round", "value", "t0", "dur_s",
               "id", "parent", "depth", "extra")

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "w")
        self._f.write(",".join(self.COLUMNS) + "\n")

    def emit(self, batch: List[Dict[str, Any]]) -> None:
        for raw in batch:
            e = sanitize_event(raw)
            extra = {k: v for k, v in e.items() if k not in self.COLUMNS}
            cells = []
            for col in self.COLUMNS[:-1]:
                v = e.get(col)
                cells.append("" if v is None else json.dumps(v))
            cells.append(json.dumps(json.dumps(extra)) if extra else "")
            self._f.write(",".join(cells) + "\n")
        self._f.flush()

    def close(self) -> None:
        self._f.close()
