"""repro.obs — round-pipeline telemetry.

Three layers (see DESIGN.md §Observability):

  * **registry** — structured metrics (counters / gauges / per-round
    series / events) buffered host-side, flushed to pluggable sinks
    (JSONL, CSV, in-memory) only at the system's own logging boundaries;
  * **tracing** — nestable monotonic-clock spans
    (``obs.span("round/dispatch")``) cheap enough for the warm loop,
    recording dispatch vs drain time separately so the async pipeline's
    overlap stays visible;
  * **jaxmon** — JAX awareness: process-wide retrace counters
    (``obs.jax_stats``), counted explicit ``device_put``/``device_get``
    transfer accounting, the ``jax.transfer_guard``-based sync auditor
    (``obs.sync_audit``) and opt-in ``jax.profiler`` capture
    (``obs.maybe_profile``).

The invariant everything here is built around: instrumentation must not
perturb the system under test — no blocking fetches in the round loop,
no added retraces, near-zero overhead when disabled (no sink attached).
Enforced by tests/test_obs.py.
"""
from repro.obs.jaxmon import (device_get, device_put, jax_stats,
                              maybe_profile, sync_audit)
from repro.obs.registry import OBS, now
from repro.obs.tracing import span

__all__ = ["OBS", "now", "span", "jax_stats", "device_put", "device_get",
           "sync_audit", "maybe_profile", "configure", "flush", "log"]

# singleton conveniences (module-level functions so call sites read as
# ``obs.log(...)`` / ``obs.flush()``)
configure = OBS.configure
flush = OBS.flush
log = OBS.log
