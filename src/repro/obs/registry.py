"""Structured metrics registry: counters, gauges, per-round scalar
series and free-form events, buffered host-side and flushed to pluggable
sinks only at the caller's logging boundaries.

The registry is the process-wide singleton :data:`OBS`.  Everything is a
no-op while no sink is attached (``OBS.enabled`` is False — the default),
so instrumented hot paths pay one attribute load + branch; with sinks the
cost per record is a dict append to a host-side buffer.  Nothing here
imports jax and nothing ever touches device values: callers hand the
registry plain Python scalars they already fetched at their own sync
points, which is what keeps instrumentation from perturbing the async
round pipeline (no extra blocking fetches, no changed dispatch order —
asserted by tests/test_obs.py).

Event stream shape (one dict per event; the JSONL sink writes one per
line, schema in :mod:`repro.obs.schema`):

  {"kind": "round", "ts": ..., "round": t, "test_acc": ..., ...}
  {"kind": "span",  "ts": ..., "name": "round/dispatch", "id": 7,
   "parent": 5, "depth": 1, "t0": ..., "dur_s": ...}
  {"kind": "counter" | "gauge", "ts": ..., "name": ..., "value": ...}
  {"kind": "jax_stats", "ts": ..., <repro.obs.jaxmon counters>}
  {"kind": "log",   "ts": ..., "msg": ...}
  {"kind": "meta",  "ts": ..., <run header: argv, wall epoch, ...>}

``ts``/``t0`` are monotonic seconds since the registry's process epoch
(``time.perf_counter`` based — immune to wall-clock steps); the ``meta``
header records the wall-clock epoch for absolute-time reconstruction.
"""
from __future__ import annotations

import sys
import threading
import time
from typing import Any, Callable, Dict, List, Optional

_EPOCH_WALL = time.time()
_EPOCH_MONO = time.perf_counter()


def now() -> float:
    """Monotonic seconds since the obs epoch (process start)."""
    return time.perf_counter() - _EPOCH_MONO


class Observability:
    """The metrics registry + event buffer.  Thread-safe; cheap when
    disabled (every record method returns after one branch)."""

    def __init__(self):
        self._lock = threading.RLock()
        self._sinks: List[Any] = []
        self._buffer: List[Dict[str, Any]] = []
        self._flush_hooks: List[Callable[[], None]] = []
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self._dirty_counters: set = set()
        self.quiet = False

    # -- lifecycle -----------------------------------------------------
    @property
    def enabled(self) -> bool:
        return bool(self._sinks)

    def add_sink(self, sink) -> None:
        with self._lock:
            self._sinks.append(sink)

    def add_flush_hook(self, hook: Callable[[], None]) -> None:
        """Hooks run at the start of every flush (while recording is
        still buffered) — e.g. jaxmon snapshots its counters here."""
        with self._lock:
            if hook not in self._flush_hooks:
                self._flush_hooks.append(hook)

    def configure(self, jsonl: Optional[str] = None,
                  csv: Optional[str] = None, memory: bool = False,
                  quiet: Optional[bool] = None):
        """Attach sinks from CLI-style options.  Returns the MemorySink
        when ``memory`` is requested (tests read its ``events``)."""
        from repro.obs.sinks import CsvSink, JsonlSink, MemorySink
        mem = None
        with self._lock:
            if jsonl:
                self.add_sink(JsonlSink(jsonl))
            if csv:
                self.add_sink(CsvSink(csv))
            if memory:
                mem = MemorySink()
                self.add_sink(mem)
            if quiet is not None:
                self.quiet = quiet
            if self._sinks:
                self.event("meta", wall_epoch=_EPOCH_WALL,
                           argv=list(sys.argv))
        return mem

    def reset(self) -> None:
        """Close sinks and drop all state (tests; start-of-run)."""
        with self._lock:
            self.flush()
            for s in self._sinks:
                close = getattr(s, "close", None)
                if close:
                    close()
            self._sinks.clear()
            self._buffer.clear()
            self.counters.clear()
            self.gauges.clear()
            self._dirty_counters.clear()
            self.quiet = False

    # -- recording (buffered; never blocks on device values) ----------
    def event(self, kind: str, **fields) -> None:
        if not self._sinks:
            return
        e = {"kind": kind, "ts": round(now(), 6)}
        e.update(fields)
        with self._lock:
            self._buffer.append(e)

    def counter(self, name: str, inc: float = 1) -> None:
        """Cumulative counter; current values are emitted as events at
        the next flush (not per increment — increments are hot)."""
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + inc
            if self._sinks:
                self._dirty_counters.add(name)

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self.gauges[name] = value
        self.event("gauge", name=name, value=value)

    def record_round(self, round: int, **scalars) -> None:
        """One per-round series row (acc/loss/E_std/mean_bid/vds_gap...).
        Callers pass host floats they already own."""
        self.event("round", round=int(round), **scalars)

    def log(self, msg: str, always: bool = False) -> None:
        """Structured stdout logger: prints ``msg`` verbatim (byte-
        compatible with the bare ``print`` it replaces) unless quiet, and
        mirrors it into the event stream when sinks are attached.
        ``always=True`` marks a result line (the command's primary
        output, e.g. ``final acc=...``) that ``--quiet`` must not
        swallow — quiet silences progress, not answers."""
        if always or not self.quiet:
            print(msg)
        self.event("log", msg=msg)

    # -- flushing (the logging boundary) -------------------------------
    def flush(self) -> None:
        """Push the buffered events to every sink.  Called only at the
        system's own logging boundaries (metric drains, end of run) so
        sink I/O never lands inside the round loop's dispatch window."""
        if not self._sinks:
            return
        with self._lock:
            for hook in self._flush_hooks:
                hook()
            for name in sorted(self._dirty_counters):
                self._buffer.append({"kind": "counter",
                                     "ts": round(now(), 6), "name": name,
                                     "value": self.counters[name]})
            self._dirty_counters.clear()
            if not self._buffer:
                return
            batch, self._buffer = self._buffer, []
            for s in self._sinks:
                s.emit(batch)


OBS = Observability()
