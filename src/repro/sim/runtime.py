"""CohortRuntime: pluggable execution backends for a round's local
training (selected via ``FLConfig.runtime`` / ``train.py --runtime``).

  * ``sequential`` — the reference oracle: one jitted local step, Python
    loops over clients and minibatches (the paper's own execution model).
  * ``vectorized`` — the repro.sim cohort engine: the whole cohort's
    local epochs run as one compiled program per size bucket (vmap over
    clients, scan over steps), with the weighted aggregation fused in.
  * ``sharded`` — the vectorized engine mesh-mapped over the cohort mesh
    (launch/mesh.make_cohort_mesh): each bucket's client axis is
    shard_map'd across the mesh's ``data`` axis with replicated params
    and an on-mesh psum FedAvg reduction, so a round's local epochs run
    on every device of the mesh instead of one.  Degrades to the
    1-device debug mesh (same program, axis size 1) on a plain host.
  * ``device`` — the device-resident fleet pipeline (repro.sim.fleet):
    all clients' data is packed once at init into per-capacity-class
    device tensors; per-round cohort assembly is an on-device gather by
    winner rows driven by tiny host-built int plans, and the compiled
    programs are keyed on *static* fleet-derived capacity classes so
    nothing retraces after warm-up.  Composes with the cohort mesh: on a
    multi-device host the per-invocation client axis is shard_map'd over
    ``data`` with a psum FedAvg, same semantics as ``sharded``.

All backends are bit-compatible in *behavior* (same shuffles, same batch
boundaries, same FedAvg weights); results agree up to float
reassociation.  The sequential backend stays the ground truth the
engine backends are tested against (tests/test_sim.py).
"""
from __future__ import annotations

import time
from typing import Any, List, Optional, Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import FLConfig
from repro.core.adapters import ModelAdapter
from repro.core.aggregation import UpdateBatch, make_flat_delta
from repro.optim import apply_updates, fedprox_grad, sgd
from repro.sim.cohort import (HostPlanCache, drop_zero_size_winners,
                              pack_cohort, pack_feature_pass)
from repro.sim.engine import CohortEngine
from repro.sim.fleet import FleetStore

RUNTIMES = ("sequential", "vectorized", "sharded", "device")


def tree_weighted_sum(trees: List[Any], weights: np.ndarray):
    """sum_k p_k * tree_k (the FedAvg reduction)."""
    out = jax.tree.map(lambda x: x * weights[0], trees[0])
    for t, w in zip(trees[1:], weights[1:]):
        out = jax.tree.map(lambda a, b: a + b * w, out, t)
    return out


class CohortRuntime(Protocol):
    """What FederatedServer needs from an execution backend."""

    name: str

    def train_cohort(self, global_params, sel_idx: np.ndarray,
                     history: np.ndarray) -> Optional[Any]:
        """Run local training for the winners and return the aggregated
        global params (None for an empty cohort). ``history`` is a HOST
        array (the server's participation mirror) — per-winner shuffle
        seeds index it directly, so the control plane never pays a
        per-client device sync for rng seeding."""
        ...

    def train_client(self, global_params, client_idx: int,
                     history_count: int) -> Any:
        """One client's local params after its local epochs."""
        ...

    def train_cohort_updates(self, global_params, sel_idx: np.ndarray,
                             history: np.ndarray):
        """Defended-path stage-3: the same local training, but instead
        of the fused FedAvg aggregate return the cohort's per-client
        flat param deltas as an UpdateBatch (repro.core.aggregation) —
        (C, D) deltas + weights + client ids, padding rows all-zero with
        id -1 — for the server's screened aggregation.  None for an
        empty cohort."""
        ...

    def cluster_features(self, global_params, key,
                         feature_kind: str) -> Optional[jnp.ndarray]:
        """(N, D) *raw* clustering features, or None to use the reference
        per-client loop in repro.core.clustering. Either way the blocked
        JL projection and the jitted k-means engine run downstream in
        clustering.cluster_clients, so both runtimes share one code path
        from raw features onward."""
        ...


# ----------------------------------------------------------------------
class SequentialRuntime:
    """Reference oracle: the seed implementation's per-client loop."""

    name = "sequential"

    def __init__(self, cfg: FLConfig, adapter: ModelAdapter,
                 x: np.ndarray, y: np.ndarray, clients):
        self.cfg = cfg
        self.adapter = adapter
        self.x, self.y = x, y
        self.clients = clients
        self._local_step = jax.jit(self._make_local_step())

    def _make_local_step(self):
        _, upd = sgd(self.cfg.lr, momentum=self.cfg.local_momentum)

        def step(params, opt_state, batch, global_params):
            g = self.adapter.grad(params, batch)
            if self.cfg.aggregator == "fedprox":
                g = fedprox_grad(g, params, global_params,
                                 self.cfg.fedprox_mu)
            u, opt_state = upd(g, opt_state, params)
            return apply_updates(params, u), opt_state

        return step

    def train_client(self, global_params, client_idx: int,
                     history_count: int):
        cfg = self.cfg
        c = self.clients[client_idx]
        x, y = self.x[c.train_idx], self.y[c.train_idx]
        init, _ = sgd(cfg.lr, momentum=cfg.local_momentum)
        p = global_params
        opt = init(p)
        bs = min(32, len(x))
        rng = np.random.default_rng(int(history_count) * 977 + client_idx)
        for _ in range(cfg.local_epochs):
            order = rng.permutation(len(x))
            for i in range(0, len(x) - bs + 1, bs):
                idx = order[i:i + bs]
                p, opt = self._local_step(
                    p, opt, {"x": x[idx], "y": y[idx]}, global_params)
        return p

    def train_cohort(self, global_params, sel_idx, history):
        history = np.asarray(history)       # host mirror; never a jnp sync
        # drop zero-size winners: they have no minibatches to run and no
        # FedAvg mass — with ALL sizes zero the old ``pk = sizes`` path
        # silently multiplied the global params by an all-zero weight
        # vector (tree_weighted_sum -> zero params)
        sel_idx = drop_zero_size_winners(sel_idx, self.clients)
        if sel_idx.size == 0:
            return None
        with obs.span("cohort/train", runtime=self.name,
                      cohort=int(sel_idx.size)):
            locals_ = [self.train_client(global_params, int(i),
                                         int(history[int(i)]))
                       for i in sel_idx]
            sizes = np.array([self.clients[int(i)].size for i in sel_idx],
                             np.float64)
            pk = sizes / sizes.sum()
            return tree_weighted_sum(locals_, pk)

    def train_cohort_updates(self, global_params, sel_idx, history):
        history = np.asarray(history)
        sel_idx = drop_zero_size_winners(sel_idx, self.clients)
        if sel_idx.size == 0:
            return None
        if getattr(self, "_flat_delta", None) is None:
            self._flat_delta = make_flat_delta(global_params)
        with obs.span("cohort/train", runtime=self.name,
                      cohort=int(sel_idx.size), defended=True):
            rows = [self._flat_delta(
                        self.train_client(global_params, int(i),
                                          int(history[int(i)])),
                        global_params)
                    for i in sel_idx]
            sizes = np.array([self.clients[int(i)].size for i in sel_idx],
                             np.float64)
            pk = sizes / sizes.sum()
            return UpdateBatch(deltas=jnp.stack(rows),
                               weights=pk.astype(np.float32),
                               client_idx=np.asarray(sel_idx, np.int32))

    def cluster_features(self, global_params, key, feature_kind):
        return None   # use the reference loop in clustering.cluster_clients


# ----------------------------------------------------------------------
class VectorizedRuntime(SequentialRuntime):
    """Cohort engine backend: one compiled program per bucket shape.

    Inherits the oracle's ``train_client`` (single-client calls have no
    batching to exploit) and overrides the cohort-level entry points.
    """

    name = "vectorized"

    def __init__(self, cfg, adapter, x, y, clients, mesh=None):
        super().__init__(cfg, adapter, x, y, clients)
        self.mesh = mesh
        self.engine = CohortEngine(adapter, cfg, mesh=mesh)
        # memoized plan structure + per-client local data shards: packing
        # rebuilds only the shuffle permutations per round
        self.plan_cache = HostPlanCache(x, y, clients, cfg.local_epochs)
        self.host_pack_s = 0.0   # cumulative host-side packing time

    def _pack(self, sel_idx, history, client_multiple=1):
        t0 = time.perf_counter()
        with obs.span("cohort/pack", winners=int(np.asarray(sel_idx).size)):
            buckets = pack_cohort(self.x, self.y, self.clients, sel_idx,
                                  history, self.cfg,
                                  client_multiple=client_multiple,
                                  cache=self.plan_cache)
        self.host_pack_s += time.perf_counter() - t0
        return buckets

    def train_cohort(self, global_params, sel_idx, history):
        with obs.span("cohort/train", runtime=self.name,
                      cohort=int(np.asarray(sel_idx).size)):
            return self.engine.train_cohort(global_params,
                                            self._pack(sel_idx, history))

    def train_cohort_updates(self, global_params, sel_idx, history):
        # the sharded runtime inherits this as-is: per-row deltas feed a
        # single-device screened reduction, so the updates program always
        # packs with client_multiple=1 and runs un-mesh-mapped (bucket
        # shapes differ from the sharded fused path — each traces once)
        buckets = self._pack(sel_idx, history)
        if not buckets:
            return None
        with obs.span("cohort/train", runtime=self.name,
                      cohort=int(np.asarray(sel_idx).size), defended=True):
            deltas = [self.engine.train_bucket_updates(global_params, b)
                      for b in buckets]
            return UpdateBatch(
                deltas=jnp.concatenate(deltas, axis=0),
                weights=np.concatenate(
                    [np.asarray(b.weights, np.float32) for b in buckets]),
                client_idx=np.concatenate(
                    [np.asarray(b.client_idx, np.int32) for b in buckets]))

    def cluster_features(self, global_params, key, feature_kind):
        with obs.span("cluster/features", feature=feature_kind,
                      runtime=self.name):
            if feature_kind == "weights":
                # the cache's epochs field is unused by the feature plan
                # (one in-order epoch); sharing it reuses the local data
                # gathers
                buckets = pack_feature_pass(
                    self.x, self.y, self.clients,
                    chunk_width=self.cfg.cohort_vmap_width,
                    cache=self.plan_cache)
                return self.engine.weight_features(global_params, buckets,
                                                   len(self.clients))
            return self.engine.gradient_features(
                global_params, *self._gather_gradient_windows(key))

    def _gather_gradient_windows(self, key):
        """Reproduce the reference feature pass's sample-window draws
        (same fold_in stream as clustering.cluster_clients) and gather
        them into uniform (N, T0, window, ...) tensors."""
        from repro.core.clustering import window_indices
        cfg = self.cfg
        t0, w = cfg.cluster_resamples, cfg.sample_window
        n = len(self.clients)
        xb = np.empty((n, t0, w) + self.x.shape[1:], self.x.dtype)
        yb = np.empty((n, t0, w), self.y.dtype)
        for i, c in enumerate(self.clients):
            shard = np.asarray(c.train_idx)
            ki = jax.random.fold_in(key, i)
            for t in range(t0):
                k = jax.random.fold_in(ki, t)
                idx = np.asarray(window_indices(k, len(shard), w))
                g = shard[idx]
                xb[i, t] = self.x[g]
                yb[i, t] = self.y[g]
        return xb, yb


# ----------------------------------------------------------------------
class ShardedRuntime(VectorizedRuntime):
    """Mesh-mapped cohort engine backend: each bucket's client axis is
    shard_map'd over the cohort mesh's ``data`` axis (replicated params,
    per-device chunked vmap/scan, on-mesh psum FedAvg).  The packer pads
    every bucket's client axis to a multiple of the data-axis size so the
    shard split is even.  Clustering feature passes inherit the
    vectorized (single-device) path: they feed stage-1 clustering, whose
    selection logs must stay bit-identical across runtimes.
    """

    name = "sharded"

    def __init__(self, cfg, adapter, x, y, clients, mesh=None):
        if mesh is None:
            from repro.launch.mesh import make_cohort_mesh
            mesh = make_cohort_mesh(cfg.cohort_mesh_devices)
        super().__init__(cfg, adapter, x, y, clients, mesh=mesh)

    def train_cohort(self, global_params, sel_idx, history):
        with obs.span("cohort/train", runtime=self.name,
                      cohort=int(np.asarray(sel_idx).size)):
            buckets = self._pack(
                sel_idx, history,
                client_multiple=self.engine.data_axis_size)
            return self.engine.train_cohort(global_params, buckets)


# ----------------------------------------------------------------------
class DeviceRuntime(VectorizedRuntime):
    """Device-resident fleet backend (repro.sim.fleet): the whole fleet's
    data lives on device in static capacity-class tensors; per-round host
    work shrinks to assembling tiny int index plans (winner rows + the
    oracle's shuffle permutations), and every compiled program is keyed
    on a fleet-derived class shape, so nothing retraces after
    :meth:`warmup`.  On a multi-device host the per-invocation client
    axis is shard_map'd over the cohort mesh's ``data`` axis (replicated
    store, psum FedAvg) — same semantics as the sharded runtime.
    Clustering feature passes inherit the vectorized path (their logs
    must stay bit-identical across runtimes)."""

    name = "device"

    def __init__(self, cfg, adapter, x, y, clients, mesh=None):
        if mesh is None:
            from repro.launch.mesh import make_cohort_mesh
            m = make_cohort_mesh(cfg.cohort_mesh_devices)
            # the 1-device debug mesh would only add shard_map overhead
            mesh = m if m.shape["data"] > 1 else None
        super().__init__(cfg, adapter, x, y, clients, mesh=mesh)
        self.store = FleetStore(x, y, clients, cfg,
                                client_multiple=self.engine.data_axis_size,
                                cache=self.plan_cache)
        # the class tensors now hold the fleet on device — don't keep a
        # host duplicate of the whole pool alive for the rest of the run
        # (a feature pass lazily re-gathers what it needs, once)
        self.plan_cache.drop_local_data()
        self._warmed = False

    def warmup(self, global_params):
        """Compile every capacity class's program up front (one fully
        masked invocation per (class, tier)) so the round loop never
        traces.  Idempotent: re-running (e.g. a second ``run()`` call)
        would re-dispatch real masked scans against a hot jit cache."""
        if self._warmed:
            return
        with obs.span("fleet/warmup", classes=len(self.store.classes)):
            for b in self.store.warmup_batches():
                c = self.store.classes[b.cls_id]
                staged = self._put_batch(b, c)
                if self.cfg.defended:
                    # defended rounds call the per-row updates program
                    # instead of the fused one — warm that variant so the
                    # screened path keeps the zero-warm-retrace guarantee
                    jax.block_until_ready(self.engine.train_class_updates(
                        global_params, *staged[:5]))
                else:
                    jax.block_until_ready(self.engine.train_class(
                        global_params, *staged))
        self._warmed = True

    def _put_batch(self, b, c):
        """Stage one class batch's host-built plan arrays on device via
        the *counted explicit* transfer wrapper.  These tiny int plans are
        the round loop's only intended h2d traffic; routing them through
        obs.device_put is what makes the warm loop pass the sync auditor
        (implicit numpy->jit transfers are disallowed there) and keeps
        the byte accounting honest."""
        rows, plans, mask, w = obs.device_put(
            (b.rows, b.plans, b.step_mask, b.weights))
        return c.x, c.y, rows, plans, mask, w

    def train_cohort(self, global_params, sel_idx, history):
        t0 = time.perf_counter()
        with obs.span("cohort/assemble",
                      winners=int(np.asarray(sel_idx).size)):
            batches = self.store.assemble(sel_idx, np.asarray(history))
        self.host_pack_s += time.perf_counter() - t0
        with obs.span("cohort/train", runtime=self.name,
                      classes=len(batches)):
            agg = None
            for b in batches:
                c = self.store.classes[b.cls_id]
                part = self.engine.train_class(global_params,
                                               *self._put_batch(b, c))
                agg = part if agg is None else jax.tree.map(jnp.add, agg,
                                                            part)
            return agg

    def train_cohort_updates(self, global_params, sel_idx, history):
        t0 = time.perf_counter()
        with obs.span("cohort/assemble",
                      winners=int(np.asarray(sel_idx).size)):
            batches = self.store.assemble(sel_idx, np.asarray(history))
        self.host_pack_s += time.perf_counter() - t0
        if not batches:
            return None
        with obs.span("cohort/train", runtime=self.name,
                      classes=len(batches), defended=True):
            parts, ws, ids = [], [], []
            for b in batches:
                c = self.store.classes[b.cls_id]
                parts.append(self.engine.train_class_updates(
                    global_params, *self._put_batch(b, c)[:5]))
                ws.append(np.asarray(b.weights, np.float32))
                ids.append(np.asarray(b.client_idx, np.int32))
            # padding rows ride along (all-zero delta, id -1, weight 0);
            # the server compacts them out before the screened program
            return UpdateBatch(deltas=jnp.concatenate(parts, axis=0),
                               weights=np.concatenate(ws),
                               client_idx=np.concatenate(ids))


# ----------------------------------------------------------------------
def make_runtime(cfg: FLConfig, adapter: ModelAdapter, x, y,
                 clients) -> CohortRuntime:
    if cfg.runtime == "sequential":
        return SequentialRuntime(cfg, adapter, x, y, clients)
    if cfg.runtime == "vectorized":
        return VectorizedRuntime(cfg, adapter, x, y, clients)
    if cfg.runtime == "sharded":
        return ShardedRuntime(cfg, adapter, x, y, clients)
    if cfg.runtime == "device":
        return DeviceRuntime(cfg, adapter, x, y, clients)
    raise ValueError(
        f"unknown FLConfig.runtime={cfg.runtime!r}; expected {RUNTIMES}")
