"""FleetStore: device-resident fleet data + compile-once capacity classes.

The host-packed runtimes (``vectorized``/``sharded``) rebuild padded
``(C, S, bs, *feat)`` minibatch tensors on the host every round and pay
an H2D copy per bucket; worse, the bucket shapes are *data-dependent* —
``(batch size, pow2 step band)`` over whichever clients won the auction —
so jit retraces whenever a round's cohort composition shifts.  The
``device`` runtime replaces both taxes:

* **Pack once.**  At server init every client's local shard is gathered
  once into a device-resident per-class store ``(P, n_cap, *feat)``
  (row-major by client, plus size/step tables).  Per-round cohort
  assembly is then an on-device ``jnp.take`` by winner rows inside the
  compiled program — the only thing the host builds per round are tiny
  int32 index tensors (winner rows + local batch plans, i.e. the oracle's
  shuffle permutations, which must stay on the host rng to remain
  bit-compatible with the sequential oracle).

* **Compile once.**  Bucket shapes are replaced by a small static set of
  **capacity classes** derived from the *fleet* at init, not the round's
  cohort: class key = (batch size, pow2 band of total local steps), step
  capacity = the class's fleet-wide max (rounded to a multiple of 4),
  client capacity = a short pow2 **tier ladder** up to the per-round
  winner bound (each tier rounded to a multiple of the mesh data-axis
  size).  Every possible winner maps to a pre-known class and every
  possible winner count to a pre-known tier, so ``CohortEngine
  .train_class`` compiles once per (class, tier) at warm-up and never
  retraces; a round whose winners in one class exceed the top tier
  simply runs the *same* compiled programs more than once (greedy
  largest-fitting-tier chunking).

Padding waste bound: within a class the pow2 step band keeps any member
below ~2x the steps of the smallest, same as the bucket path; the pow2
tier ladder keeps client-axis padding below 2x the invocation's real
winner count (exactly the bucket packer's ``next_pow2`` bound; masked
rows are weight-0 and drop out of the FedAvg sum exactly), at the cost
of one warm-up compile per (class, tier).  See DESIGN.md §Round
pipeline.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import FLConfig
from repro.core.selection import k_per_cluster
from repro.sim.cohort import HostPlanCache, _next_pow2, _round_up


@dataclass
class CapacityClass:
    """One static shape class of the fleet (one compiled program per
    client-capacity tier).

    ``x (P, n_cap, *feat)`` / ``y (P, n_cap)`` are the device-resident
    local shards of the class's ``P`` members, each padded to the class
    max size ``n_cap`` (plans never index the padding).  ``tiers`` is
    the ascending pow2 ladder of padded client-axis sizes an invocation
    may use (every tier a multiple of the mesh data-axis size).
    """

    bs: int
    step_cap: int            # padded step axis (multiple of 4)
    tiers: List[int]         # padded client-axis capacities (ascending)
    n_cap: int
    members: np.ndarray      # (P,) global client ids
    x: jnp.ndarray
    y: jnp.ndarray

    @property
    def client_cap(self) -> int:
        """Largest per-invocation client capacity (the top tier)."""
        return self.tiers[-1]


@dataclass
class ClassBatch:
    """One per-round invocation of a capacity class's program.

    ``rows (C_cap,)`` int32 rows into the class store (0 for padding —
    masked out), ``plans (C_cap, step_cap, bs)`` int32 local sample
    indices, ``step_mask (C_cap, step_cap)`` float32, ``weights (C_cap,)``
    float32 *global* FedAvg weights (over all invocations they sum to 1),
    ``client_idx (C_cap,)`` int32 global ids (-1 for padding).
    """

    cls_id: int
    rows: np.ndarray
    plans: np.ndarray
    step_mask: np.ndarray
    weights: np.ndarray
    client_idx: np.ndarray


class FleetStore:
    """Pack the whole fleet once; assemble cohorts as index tensors."""

    def __init__(self, x: np.ndarray, y: np.ndarray, clients,
                 cfg: FLConfig, client_multiple: int = 1,
                 cache: HostPlanCache | None = None):
        self.cfg = cfg
        self.cache = cache if cache is not None \
            else HostPlanCache(x, y, clients, cfg.local_epochs)
        n = len(clients)
        total_steps = self.cache.steps * cfg.local_epochs
        self.class_of = np.full((n,), -1, np.int64)
        self.row_of = np.full((n,), -1, np.int64)

        groups: Dict[tuple, List[int]] = {}
        for i in range(n):
            if self.cache.sizes[i] == 0:     # no steps, no FedAvg mass
                continue
            key = (int(self.cache.bs[i]),
                   _next_pow2(max(int(total_steps[i]), 1)))
            groups.setdefault(key, []).append(i)

        # per-round winner bound: k_total overall, but per-cluster floors
        # can push the union above it (num_clusters x K_j)
        k_total = max(int(round(cfg.select_ratio * cfg.num_clients)), 1)
        k_bound = max(k_total, cfg.num_clusters * k_per_cluster(cfg))
        mult = max(int(client_multiple), 1)

        self.classes: List[CapacityClass] = []
        for (bs, _band), members in sorted(groups.items()):
            members = np.asarray(members, np.int64)
            n_cap = int(self.cache.sizes[members].max())
            step_cap = _round_up(int(total_steps[members].max()), 4)
            cap = min(len(members), k_bound)
            # pow2 ladder 1, 2, 4, ... up to the winner bound, every tier
            # rounded to the mesh data-axis multiple (rounding collapses
            # small tiers on big meshes — dedupe keeps the set tight)
            tiers, t = [], 1
            while t < cap:
                tiers.append(_round_up(t, mult))
                t *= 2
            tiers.append(_round_up(cap, mult))
            tiers = sorted(set(tiers))
            xb = np.zeros((len(members), n_cap) + x.shape[1:], x.dtype)
            yb = np.zeros((len(members), n_cap), y.dtype)
            for r, gid in enumerate(members):
                xl, yl = self.cache.local_data(int(gid))
                xb[r, :len(xl)] = xl
                yb[r, :len(yl)] = yl
                self.class_of[gid] = len(self.classes)
                self.row_of[gid] = r
            # the one-time fleet pack IS a real host->device transfer —
            # route it through the counted explicit wrapper so the obs
            # byte books include it and the warm loop stays implicit-free
            xd, yd = obs.device_put((xb, yb))
            self.classes.append(CapacityClass(
                bs=bs, step_cap=step_cap, tiers=tiers, n_cap=n_cap,
                members=members, x=xd, y=yd))

    # ------------------------------------------------------------------
    def _empty_batch(self, cls_id: int, tier: int) -> ClassBatch:
        c = self.classes[cls_id]
        return ClassBatch(
            cls_id=cls_id,
            rows=np.zeros((tier,), np.int32),
            plans=np.zeros((tier, c.step_cap, c.bs), np.int32),
            step_mask=np.zeros((tier, c.step_cap), np.float32),
            weights=np.zeros((tier,), np.float32),
            client_idx=np.full((tier,), -1, np.int32))

    def warmup_batches(self) -> List[ClassBatch]:
        """One fully-masked invocation per (class, tier): running each
        through ``CohortEngine.train_class`` compiles every program the
        fleet can ever need (classes and tiers are static), so the round
        loop never traces."""
        return [self._empty_batch(i, t)
                for i, c in enumerate(self.classes) for t in c.tiers]

    def assemble(self, sel_idx: np.ndarray,
                 history: np.ndarray) -> List[ClassBatch]:
        """Index tensors for the round's winners.  ``history`` is the
        pre-round host participation mirror (seeds the shuffle rng).
        Zero-size winners are dropped (same rule as the packers); an
        all-zero cohort assembles to [] — skip aggregation."""
        sel_idx = np.asarray(sel_idx)
        if sel_idx.size:
            sel_idx = sel_idx[self.cache.sizes[sel_idx] > 0]
        if sel_idx.size == 0:
            return []
        sizes = self.cache.sizes[sel_idx].astype(np.float64)
        pk = sizes / sizes.sum()

        by_cls: Dict[int, List[tuple]] = {}
        for i, p in zip(sel_idx, pk):
            by_cls.setdefault(int(self.class_of[int(i)]), []).append(
                (int(i), float(p)))

        out = []
        for cls_id, winners in sorted(by_cls.items()):
            c = self.classes[cls_id]
            lo = 0
            while lo < len(winners):
                rem = len(winners) - lo
                # greedy largest tier that the remainder fills; when even
                # the smallest tier is bigger, take it (padding < 2x rem)
                fits = [t for t in c.tiers if t <= rem]
                tier = fits[-1] if fits else c.tiers[0]
                chunk = winners[lo:lo + tier]
                lo += len(chunk)
                b = self._empty_batch(cls_id, tier)
                for r, (gid, p) in enumerate(chunk):
                    plan = self.cache.plan(gid, int(history[gid]))
                    s = plan.shape[0]
                    b.rows[r] = self.row_of[gid]
                    b.plans[r, :s] = plan
                    b.step_mask[r, :s] = 1.0
                    b.weights[r] = p
                    b.client_idx[r] = gid
                out.append(b)
        return out
