"""Cohort packing: turn a set of selected clients' index shards into the
dense, padded minibatch tensors the vectorized engine consumes.

The sequential oracle (``SequentialRuntime.train_client``) iterates, per
client, ``local_epochs`` shuffled passes of full minibatches of size
``bs = min(32, n)`` and *drops the remainder batch* — so every executed
step sees exactly ``bs`` real samples.  Packing therefore never pads
*inside* a batch; it only pads along

  * the **step axis** — clients with fewer steps than the bucket maximum
    get trailing dummy steps whose per-step mask is 0 (the engine turns a
    masked step into the identity), and
  * the **client axis** — each bucket is padded to a multiple of the
    engine's vmap chunk width with weight-0 dummy clients.

Clients with different batch sizes (only those with fewer than 32 local
samples) cannot share a tensor, and clients with wildly different step
counts would waste compute on padding, so the cohort is split into
**buckets** keyed by ``(batch size, power-of-two step band)``: within a
bucket no client runs more than ~2x the steps of another.  The engine
runs each bucket separately and the bucket partial aggregates (computed
against the *global* cohort weights) sum to the full FedAvg update.

The shuffle stream matches the oracle bit-for-bit: the same
``np.random.default_rng(history * 977 + client_idx)`` seed and the same
per-epoch ``permutation`` draws.

Everything about a client's plan EXCEPT the permutation values — its
batch size, step count, and the ``x[shard]`` local data gather — depends
only on the shard, so :class:`HostPlanCache` memoizes those at runtime
init and per-round packing rebuilds only the permutations (the old path
re-derived the plan structure and re-gathered ``x[shard[plan]]`` from the
full global pool every round).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.configs.base import FLConfig


@dataclass
class CohortBucket:
    """One homogeneous slice of a cohort (shared batch size).

    Shapes: ``xb (C, S, bs, *feat)``, ``yb (C, S, bs)``, ``step_mask
    (C, S)`` float32 (1 = real step), ``weights (C,)`` float32 global
    aggregation weights (over *all* buckets they sum to 1; padded rows are
    0), ``client_idx (C,)`` int32 global client ids (-1 for padding).
    """

    client_idx: np.ndarray
    xb: np.ndarray
    yb: np.ndarray
    step_mask: np.ndarray
    weights: np.ndarray
    batch_size: int

    @property
    def num_clients(self) -> int:
        return int(self.client_idx.shape[0])

    @property
    def num_steps(self) -> int:
        return int(self.step_mask.shape[1])


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _round_up(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def drop_zero_size_winners(sel_idx: np.ndarray, clients) -> np.ndarray:
    """Winners with no local samples run no steps and carry no FedAvg
    mass — drop them before packing/weighting (shared by the sequential
    oracle and the packer so the drop rule can never desynchronize)."""
    sel_idx = np.asarray(sel_idx)
    if sel_idx.size == 0:
        return sel_idx
    return sel_idx[[clients[int(i)].size > 0 for i in sel_idx]]


def oracle_batch_plan(n: int, bs: int, epochs: int,
                      rng: np.random.Generator) -> np.ndarray:
    """The exact (epochs * steps, bs) local-index plan the sequential
    oracle executes: per epoch one ``rng.permutation(n)`` draw, then full
    minibatches of ``bs`` with the remainder dropped."""
    steps = (n - bs) // bs + 1 if n >= bs else 0
    out = np.empty((epochs * steps, bs), np.int64)
    r = 0
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - bs + 1, bs):
            out[r] = order[i:i + bs]
            r += 1
    return out


def sequential_batch_plan(n: int, bs: int) -> np.ndarray:
    """The clustering feature pass's plan: one epoch, natural order, full
    minibatches, remainder dropped (mirrors the ``local_steps_fn`` loop)."""
    steps = (n - bs) // bs + 1 if n >= bs else 0
    return np.arange(steps * bs, dtype=np.int64).reshape(steps, bs)


class HostPlanCache:
    """Per-client plan structure and local data shards, memoized once.

    ``oracle_batch_plan`` is (permutation, structure): the batch size,
    per-epoch step count and batch boundaries depend only on the shard
    size and ``local_epochs``; only the permutation values depend on the
    history-seeded rng.  The cache precomputes the structure (and the
    ``x[shard]``/``y[shard]`` local copies, gathered lazily once per
    client) so :func:`pack_cohort` rebuilds just the per-epoch
    permutations per round and gathers minibatches from the small
    contiguous local arrays instead of the global pool.

    :meth:`plan` returns *local* sample indices (into the client's own
    shard), bit-identical to ``shardless`` composition of the oracle:
    ``shard[oracle_batch_plan(...)] == local_data[plan(...)]`` row for
    row (tests/test_fleet.py).
    """

    def __init__(self, x: np.ndarray, y: np.ndarray, clients,
                 epochs: int):
        self.epochs = int(epochs)
        self._x, self._y = x, y
        self.shards = [np.asarray(c.train_idx) for c in clients]
        self.sizes = np.array([len(s) for s in self.shards], np.int64)
        self.bs = np.minimum(32, self.sizes)
        # full minibatches of bs with the remainder dropped = n // bs
        self.steps = np.where(self.sizes > 0,
                              self.sizes // np.maximum(self.bs, 1), 0)
        self._local: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}

    def local_data(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        """(x[shard], y[shard]) for client ``i``, gathered once."""
        got = self._local.get(i)
        if got is None:
            s = self.shards[i]
            got = self._local[i] = (self._x[s], self._y[s])
        return got

    def drop_local_data(self) -> None:
        """Release the memoized host copies (lazily re-gathered on next
        use).  The device runtime calls this after the fleet store has
        packed them onto the device — keeping a full host duplicate of
        the pool alive for the whole run would defeat 'pack once'."""
        self._local.clear()

    def plan(self, i: int, history_count: int) -> np.ndarray:
        """The oracle's (epochs * steps, bs) plan in LOCAL indices: only
        the ``rng.permutation`` draws are recomputed per call."""
        n, bs = int(self.sizes[i]), int(self.bs[i])
        s = int(self.steps[i])
        rng = np.random.default_rng(int(history_count) * 977 + int(i))
        out = np.empty((self.epochs * s, bs), np.int64)
        for e in range(self.epochs):
            order = rng.permutation(n)
            out[e * s:(e + 1) * s] = order[:s * bs].reshape(s, bs)
        return out


def _pack_plans(locals_xy: Sequence[Tuple[np.ndarray, np.ndarray]],
                plans: Sequence[np.ndarray],
                client_ids: Sequence[int],
                weights: Sequence[float],
                chunk_width: int = 4,
                client_multiple: int = 1) -> List[CohortBucket]:
    """Group (plan, local shard) pairs into (batch size, pow2 step band)
    buckets and materialize the padded tensors.  ``locals_xy[m]`` holds
    member m's (x_local, y_local) data and ``plans[m]`` indexes into it
    (local indices).  ``client_multiple`` forces the padded client axis to
    a multiple of the mesh's data-axis size so a sharded bucket splits
    evenly across devices."""
    by_key: Dict[tuple, List[int]] = {}
    for pos, plan in enumerate(plans):
        key = (plan.shape[1], _next_pow2(max(plan.shape[0], 1)))
        by_key.setdefault(key, []).append(pos)

    x0, y0 = locals_xy[0]
    buckets = []
    for (bs, _band), members in sorted(by_key.items()):
        s_max = _round_up(max(plans[m].shape[0] for m in members), 4)
        # multiple of the vmap chunk width, but never beyond next-pow2
        # (a 2-client bucket padded to 4 would double its compute)
        c_pad = min(_round_up(len(members), chunk_width),
                    _next_pow2(len(members)))
        c_pad = _round_up(c_pad, client_multiple)
        xb = np.zeros((c_pad, s_max, bs) + x0.shape[1:], x0.dtype)
        yb = np.zeros((c_pad, s_max, bs), y0.dtype)
        mask = np.zeros((c_pad, s_max), np.float32)
        w = np.zeros((c_pad,), np.float32)
        cid = np.full((c_pad,), -1, np.int32)
        for row, m in enumerate(members):
            plan = plans[m]
            xl, yl = locals_xy[m]
            s = plan.shape[0]
            xb[row, :s] = xl[plan]                     # (s, bs, *feat)
            yb[row, :s] = yl[plan]
            mask[row, :s] = 1.0
            w[row] = weights[m]
            cid[row] = client_ids[m]
        buckets.append(CohortBucket(client_idx=cid, xb=xb, yb=yb,
                                    step_mask=mask, weights=w,
                                    batch_size=bs))
    if obs.OBS.enabled:
        # padding efficiency counters (emitted at the next flush): how
        # many bucket programs ran and how many padded client rows they
        # carried vs real members
        obs.OBS.counter("pack/buckets", len(buckets))
        obs.OBS.counter("pack/client_rows",
                        sum(b.weights.shape[0] for b in buckets))
        obs.OBS.counter("pack/real_clients", len(plans))
    return buckets


def pack_cohort(x: np.ndarray, y: np.ndarray, clients,
                sel_idx: np.ndarray, history: np.ndarray,
                cfg: FLConfig, client_multiple: int = 1,
                cache: Optional[HostPlanCache] = None
                ) -> List[CohortBucket]:
    """Pack the round's winners for the engine.

    ``history`` is the pre-round participation count per client (it seeds
    the oracle's shuffle rng).  Aggregation weights are the oracle's
    ``p_k = n_k / sum n_k`` over the whole cohort.  Winners with zero
    local samples contribute no steps and no FedAvg weight, so they are
    dropped up front (an all-zero cohort packs to [] — the runtimes treat
    that as "skip aggregation" instead of zeroing the global params).

    ``cache`` carries the memoized plan structure + local data shards
    across rounds; without one a throwaway cache is built (same result,
    no reuse).
    """
    sel_idx = drop_zero_size_winners(sel_idx, clients)
    if sel_idx.size == 0:
        return []
    if cache is None:
        cache = HostPlanCache(x, y, clients, cfg.local_epochs)
    sizes = cache.sizes[sel_idx].astype(np.float64)
    pk = sizes / sizes.sum()

    locals_xy = [cache.local_data(int(i)) for i in sel_idx]
    plans = [cache.plan(int(i), int(history[int(i)])) for i in sel_idx]
    return _pack_plans(locals_xy, plans, [int(i) for i in sel_idx],
                       [float(p) for p in pk],
                       chunk_width=cfg.cohort_vmap_width,
                       client_multiple=client_multiple)


def pack_feature_pass(x: np.ndarray, y: np.ndarray, clients,
                      chunk_width: int = 4,
                      cache: Optional[HostPlanCache] = None
                      ) -> List[CohortBucket]:
    """Pack *all* clients for the clustering weight-feature pass: one
    in-order epoch per client (no shuffle), unit weights (features are
    returned per client, not aggregated)."""
    if cache is None:
        cache = HostPlanCache(x, y, clients, 1)
    locals_xy, plans = [], []
    for i in range(len(clients)):
        locals_xy.append(cache.local_data(i))
        plans.append(sequential_batch_plan(int(cache.sizes[i]),
                                           int(cache.bs[i])))
    ids = list(range(len(clients)))
    return _pack_plans(locals_xy, plans, ids, [1.0] * len(clients),
                       chunk_width=chunk_width)
