"""Vectorized cohort engine: one compiled program per bucket shape.

Three compiled entry points, all ``jax.vmap`` over the client axis with a
``jax.lax.scan`` over minibatch steps inside:

When constructed with a ``mesh`` (the sharded runtime), the round-training
entry point additionally maps each bucket's client axis across the mesh's
``data`` axis with ``shard_map``: params are replicated in, every device
runs the same chunked vmap/scan program over its C/ndev slice of the
bucket tensors, and the weighted FedAvg partial sum is reduced on-mesh
with a ``psum`` — so the per-round result comes back replicated and only
the reduction order differs from the single-device program (same
float-reassociation tolerance class as vectorized-vs-sequential).  The
packer pads the client axis to a multiple of the data-axis size, so the
shard split is always even; feature passes stay on the single-device path
(they feed stage-1 clustering, whose logs must be bit-identical across
runtimes).

  * :meth:`CohortEngine.train_bucket` — the round's local training: every
    client runs ``local_epochs`` of SGD (optionally FedProx-proximal)
    from the shared global params; masked (padding) steps are the
    identity on both params and optimizer state; the bucket's weighted
    FedAvg partial sum is fused into the same program.
  * :meth:`CohortEngine.weight_features` — the Wang-et-al clustering
    feature: flattened param delta after one in-order epoch of plain SGD.
  * :meth:`CohortEngine.gradient_features` — the paper's clustering
    feature: mean flattened gradient over the T0 sample-window draws.
  * :meth:`CohortEngine.train_class` — the device-resident twin of
    ``train_bucket`` for the ``device`` runtime (repro.sim.fleet): instead
    of consuming host-packed ``(C, S, bs, ...)`` minibatch tensors it
    takes a capacity class's resident ``(P, n_cap, *feat)`` store plus
    tiny per-round int tensors (winner rows + local batch plans) and
    gathers each step's minibatch *inside* the compiled program
    (``jnp.take`` by winner row, then a per-step take over the plan), so
    per-round host work is index assembly only — no sample ever crosses
    host->device after init.  Capacity classes are static (derived from
    the whole fleet at init), so these programs compile once per class;
    ``CohortEngine.stats`` counts traces and per-shape cache hits/misses
    to make "zero retraces after warm-up" assertable.

``jax.jit`` retraces per distinct bucket shape ``(C, S, bs)``; the packer
pads C to a multiple of the vmap chunk width, S to a multiple of 4, and
band-buckets step counts by power of two to keep that cache small.  The client axis is processed in ``cfg.cohort_vmap_width``-wide
vmap chunks under an outer ``jax.lax.map``: a full-width vmap multiplies
the per-op working set by C and thrashes the CPU cache (measured 1.4-2x
slower than the loop for the paper's CNNs), while narrow chunks keep
each op cache-resident and still amortize dispatch to one call per
bucket.  Equivalence with the sequential oracle is exact up to float
reassociation (tested in tests/test_sim.py).
"""
from __future__ import annotations

from typing import Any, List, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs.base import FLConfig
from repro.core.adapters import ModelAdapter
from repro.optim import apply_updates, fedprox_grad, sgd
from repro.sim.cohort import CohortBucket


def _flatten_tree(tree) -> jnp.ndarray:
    return jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(tree)])


def _chunk_width(c: int, width: int) -> int:
    """Largest power of two <= width that divides c."""
    w = 1
    while w * 2 <= min(width, c) and c % (w * 2) == 0:
        w *= 2
    return w


def _client_map(fn, args: Tuple[jnp.ndarray, ...], width: int):
    """Map ``fn`` over the leading client axis of every array in ``args``:
    vmap in ``width``-wide chunks under an outer ``lax.map`` (see module
    docstring for why not one full-width vmap)."""
    c = args[0].shape[0]
    w = _chunk_width(c, width)
    if w == c:
        return jax.vmap(fn)(*args)
    re = tuple(a.reshape((c // w, w) + a.shape[1:]) for a in args)
    chunks = jax.lax.map(lambda ch: jax.vmap(fn)(*ch), re)
    return jax.tree.map(lambda a: a.reshape((c,) + a.shape[2:]), chunks)


class CohortEngine:
    def __init__(self, adapter: ModelAdapter, cfg: FLConfig, mesh=None):
        self.adapter = adapter
        self.cfg = cfg
        self.mesh = mesh
        # compile bookkeeping for the round-training programs: ``traces``
        # increments inside the traced bodies (runs only when XLA
        # (re)compiles); hits/misses track per-call shape-signature reuse.
        self.stats = {"traces": 0, "shape_hits": 0, "shape_misses": 0}
        self._seen_shapes = set()
        self._train = self._build_train()      # jitted inside the builder
        self._train_sharded = (self._build_train_sharded()
                               if mesh is not None else None)
        self._train_gather = self._build_train_gather()
        self._train_gather_sharded = (self._build_train_gather_sharded()
                                      if mesh is not None else None)
        # per-client flat-delta twins for the defended aggregation path
        # (repro.core.aggregation): built lazily — defense-off runs never
        # construct them, so their jit caches can't perturb anything
        self._train_updates = None
        self._train_gather_updates = None
        self._weight_feats = jax.jit(self._build_weight_features())
        self._grad_feats = jax.jit(self._build_gradient_features())

    @property
    def data_axis_size(self) -> int:
        """Client-axis shard count (1 when unsharded)."""
        return 1 if self.mesh is None else self.mesh.shape["data"]

    def _note_shape(self, key) -> None:
        hit = key in self._seen_shapes
        if hit:
            self.stats["shape_hits"] += 1
        else:
            self._seen_shapes.add(key)
            self.stats["shape_misses"] += 1
        obs.jax_stats.note_shape(hit)   # process-wide mirror

    # ------------------------------------------------------------------
    def _masked_step(self, opt_update, proximal: bool, global_params):
        """One masked local SGD step shared by both scan flavors: a
        masked (padding) step is the identity on params AND opt state."""

        def apply(p, opt, xs, ys, m):
            g = self.adapter.grad(p, {"x": xs, "y": ys})
            if proximal:
                g = fedprox_grad(g, p, global_params, self.cfg.fedprox_mu)
            u, opt2 = opt_update(g, opt, p)
            p2 = apply_updates(p, u)
            keep = m > 0.5
            return jax.tree.map(lambda a, b: jnp.where(keep, b, a),
                                (p, opt), (p2, opt2))

        return apply

    def _local_scan(self, params0, opt_init, opt_update, xb, yb, mask,
                    global_params, proximal: bool):
        """Scan ``local_step`` over the step axis for one client."""
        upd = self._masked_step(opt_update, proximal, global_params)

        def step(carry, inp):
            xs, ys, m = inp
            return upd(*carry, xs, ys, m), None

        (p, _), _ = jax.lax.scan(step, (params0, opt_init(params0)),
                                 (xb, yb, mask))
        return p

    def _local_scan_gather(self, params0, opt_init, opt_update, x_row,
                           y_row, plan, mask, global_params,
                           proximal: bool):
        """The device-resident twin of :meth:`_local_scan`: the scan
        carries the client's resident (n_cap, *feat) data and gathers
        each step's (bs,) minibatch by plan indices — the padded
        (S, bs, *feat) tensor of the host-packed path is never
        materialized."""
        upd = self._masked_step(opt_update, proximal, global_params)

        def step(carry, inp):
            idx, m = inp
            xs = jnp.take(x_row, idx, axis=0)
            ys = jnp.take(y_row, idx, axis=0)
            return upd(*carry, xs, ys, m), None

        (p, _), _ = jax.lax.scan(step, (params0, opt_init(params0)),
                                 (plan, mask))
        return p

    def _build_train_core(self):
        """Shared round-training body used by both the single-device and
        the mesh-mapped builders: per-client local scans (chunked vmap)
        plus the f32 weighted FedAvg partial.  Returns (stacked, partial)
        — callers finish the reduction (astype, or psum + astype)."""
        cfg = self.cfg
        init, upd = sgd(cfg.lr, momentum=cfg.local_momentum)
        proximal = cfg.aggregator == "fedprox"

        def core(global_params, xb, yb, mask, weights):
            self.stats["traces"] += 1      # runs at trace time only
            obs.jax_stats.note_trace("cohort_engine")

            def one_client(cx, cy, cm):
                return self._local_scan(global_params, init, upd, cx, cy,
                                        cm, global_params, proximal)

            stacked = _client_map(one_client, (xb, yb, mask),
                                  cfg.cohort_vmap_width)
            partial = jax.tree.map(
                lambda leaf: jnp.tensordot(weights,
                                           leaf.astype(jnp.float32),
                                           axes=1),
                stacked)
            return stacked, partial

        return core

    def _build_train(self):
        core = self._build_train_core()

        def train(global_params, xb, yb, mask, weights,
                  return_stacked=False):
            stacked, partial = core(global_params, xb, yb, mask, weights)
            agg = jax.tree.map(lambda p, s: p.astype(s.dtype),
                               partial, stacked)
            # only materialize the (C, ...) per-client trees as a jit
            # output when asked — the round loop needs just the aggregate
            # (XLA drops the unfetched stacked outputs otherwise)
            return (stacked, agg) if return_stacked else agg

        return jax.jit(train, static_argnames="return_stacked")

    def _flat_deltas(self, stacked, global_params) -> jnp.ndarray:
        """(C, D) float32 flat param deltas from a stacked (leading-C)
        per-client tree — leaf/concat order is jax.tree.leaves, matching
        repro.core.aggregation's flatten/apply helpers."""
        flats = jax.tree.map(
            lambda s, g: (s.astype(jnp.float32) - g[None].astype(
                jnp.float32)).reshape(s.shape[0], -1),
            stacked, global_params)
        return jnp.concatenate(jax.tree.leaves(flats), axis=1)

    def _build_train_updates(self):
        """Per-client flat-delta twin of ``_build_train`` for the
        defended aggregation path: same local scans, but instead of the
        fused FedAvg partial it returns the (C, D) update matrix the
        screened aggregation consumes.  Single-device only — the
        defended path's screening program is a single-device reduction
        anyway (see DESIGN.md §Threat model)."""
        core = self._build_train_core()

        def train(global_params, xb, yb, mask):
            stacked, _ = core(global_params, xb, yb, mask,
                              jnp.zeros((xb.shape[0],), jnp.float32))
            return self._flat_deltas(stacked, global_params)

        return jax.jit(train)

    def _build_train_sharded(self):
        """The mesh-mapped twin of ``_build_train``: shard_map over the
        'data' axis, per-device chunked vmap/scan, FedAvg partial reduced
        with an on-mesh psum.  Only the aggregate is returned (the stacked
        per-client trees would live sharded on-device; the inspection path
        stays on the single-device program)."""
        from repro.sharding.rules import (cohort_bucket_specs,
                                          cohort_param_spec)
        shard_map = getattr(jax, "shard_map", None)
        if shard_map is None:   # pre-0.6 jax keeps it under experimental
            from jax.experimental.shard_map import shard_map
        core = self._build_train_core()

        def shard_body(global_params, xb, yb, mask, weights):
            stacked, partial = core(global_params, xb, yb, mask, weights)
            # psum the per-device partial across 'data' — weights are
            # global (they sum to 1 over ALL shards of ALL buckets), so
            # shard partials just add, same as bucket partials
            return jax.tree.map(
                lambda p, s: jax.lax.psum(p, "data").astype(s.dtype),
                partial, stacked)

        train = shard_map(
            shard_body, mesh=self.mesh,
            in_specs=(cohort_param_spec(),) + cohort_bucket_specs(),
            out_specs=cohort_param_spec())
        return jax.jit(train)

    def _build_train_gather_core(self):
        """Round-training body for the device-resident fleet path: take
        the winners' rows out of the class store, run the same chunked
        vmap/scan as the bucket path with per-step index gathers, and
        fuse the f32 weighted FedAvg partial.  Returns (stacked,
        partial) — callers pick one (XLA drops the unfetched output) and
        finish the reduction (astype, or psum + astype)."""
        cfg = self.cfg
        init, upd = sgd(cfg.lr, momentum=cfg.local_momentum)
        proximal = cfg.aggregator == "fedprox"

        def core(global_params, class_x, class_y, rows, plans, mask,
                 weights):
            self.stats["traces"] += 1      # runs at trace time only
            obs.jax_stats.note_trace("cohort_engine")
            xg = jnp.take(class_x, rows, axis=0)   # (C, n_cap, *feat)
            yg = jnp.take(class_y, rows, axis=0)

            def one_client(x_row, y_row, plan, m):
                return self._local_scan_gather(global_params, init, upd,
                                               x_row, y_row, plan, m,
                                               global_params, proximal)

            stacked = _client_map(one_client, (xg, yg, plans, mask),
                                  cfg.cohort_vmap_width)
            partial = jax.tree.map(
                lambda leaf: jnp.tensordot(weights,
                                           leaf.astype(jnp.float32),
                                           axes=1),
                stacked)
            return stacked, partial

        return core

    def _build_train_gather(self):
        core = self._build_train_gather_core()

        def train(global_params, class_x, class_y, rows, plans, mask,
                  weights):
            _, partial = core(global_params, class_x, class_y, rows,
                              plans, mask, weights)
            return jax.tree.map(lambda p, g: p.astype(g.dtype),
                                partial, global_params)

        return jax.jit(train)

    def _build_train_gather_updates(self):
        """Per-client flat-delta twin of ``_build_train_gather`` for the
        defended aggregation path: one compiled program per (class,
        tier) shape — warmed alongside the aggregate programs by
        DeviceRuntime.warmup when defenses are on, so the warm loop
        still never retraces — returning the (C_cap, D) update matrix
        (padding rows all-zero: masked scans are the identity, so a
        padded row's params equal the globals)."""
        core = self._build_train_gather_core()

        def train(global_params, class_x, class_y, rows, plans, mask):
            stacked, _ = core(global_params, class_x, class_y, rows,
                              plans, mask,
                              jnp.zeros((rows.shape[0],), jnp.float32))
            return self._flat_deltas(stacked, global_params)

        return jax.jit(train)

    def _build_train_gather_sharded(self):
        """Mesh-mapped twin of ``_build_train_gather``: the class store
        stays replicated (each device gathers its own winners' rows), the
        per-invocation tensors shard their client axis over 'data', and
        the FedAvg partial is psum-reduced on-mesh."""
        from repro.sharding.rules import (cohort_param_spec,
                                          fleet_class_specs)
        shard_map = getattr(jax, "shard_map", None)
        if shard_map is None:   # pre-0.6 jax keeps it under experimental
            from jax.experimental.shard_map import shard_map
        core = self._build_train_gather_core()

        def shard_body(global_params, class_x, class_y, rows, plans,
                       mask, weights):
            _, partial = core(global_params, class_x, class_y, rows,
                              plans, mask, weights)
            return jax.tree.map(
                lambda p, g: jax.lax.psum(p, "data").astype(g.dtype),
                partial, global_params)

        train = shard_map(
            shard_body, mesh=self.mesh,
            in_specs=(cohort_param_spec(),) + fleet_class_specs(),
            out_specs=cohort_param_spec())
        return jax.jit(train)

    def _build_weight_features(self):
        cfg = self.cfg
        init, upd = sgd(cfg.lr)   # the feature pass uses plain SGD

        def features(global_params, xb, yb, mask):
            def one_client(cx, cy, cm):
                p = self._local_scan(global_params, init, upd, cx, cy, cm,
                                     global_params, proximal=False)
                delta = jax.tree.map(lambda a, b: a - b, p, global_params)
                return _flatten_tree(delta)

            return _client_map(one_client, (xb, yb, mask),
                               self.cfg.cohort_vmap_width)

        return features

    def _build_gradient_features(self):
        def features(params, xb, yb):
            def one_client(cx, cy):
                def body(_, inp):
                    xs, ys = inp
                    g = self.adapter.grad(params, {"x": xs, "y": ys})
                    return None, _flatten_tree(g)

                _, flats = jax.lax.scan(body, None, (cx, cy))
                return flats.mean(0)

            return _client_map(one_client, (xb, yb),
                               self.cfg.cohort_vmap_width)

        return features

    # ------------------------------------------------------------------
    def train_bucket(self, global_params, bucket: CohortBucket
                     ) -> Tuple[Any, Any]:
        """Returns (stacked per-client params with leading C axis,
        weighted partial aggregate sum_c w_c * params_c).  The stacked
        trees are for inspection/tests; the round loop uses
        :meth:`train_cohort`, which skips materializing them."""
        return self._train(global_params, bucket.xb, bucket.yb,
                           bucket.step_mask, bucket.weights,
                           return_stacked=True)

    def train_cohort(self, global_params, buckets: List[CohortBucket]):
        """Aggregated params over all buckets, or None for an empty
        cohort.  Weights are global, so bucket partials just add.  With a
        mesh, each bucket runs mesh-mapped (client axis over 'data') and
        its partial arrives already psum-reduced and replicated."""
        step = self._train_sharded if self._train_sharded is not None \
            else self._train
        agg = None
        for b in buckets:
            self._note_shape(("bucket", b.xb.shape))
            part = step(global_params, b.xb, b.yb, b.step_mask, b.weights)
            agg = part if agg is None else jax.tree.map(
                jnp.add, agg, part)
        return agg

    def train_bucket_updates(self, global_params, bucket: CohortBucket
                             ) -> jnp.ndarray:
        """(C, D) float32 per-client flat deltas for one bucket — the
        defended aggregation path's stage-3 output (padding rows are
        all-zero; bucket.client_idx marks them -1).  Compiles per bucket
        shape like ``train_bucket`` (the defended path adds no *warm*
        retraces beyond the bucket shapes the plain path already pays)."""
        if self._train_updates is None:
            self._train_updates = self._build_train_updates()
        self._note_shape(("bucket_upd", bucket.xb.shape))
        return self._train_updates(global_params, bucket.xb, bucket.yb,
                                   bucket.step_mask)

    def train_class(self, global_params, class_x, class_y, rows, plans,
                    step_mask, weights):
        """One capacity-class invocation of the device-resident round
        trainer: ``class_x/class_y`` are the class's resident ``(P,
        n_cap, ...)`` store, the rest are the per-round ``(C_cap, ...)``
        index/weight tensors (repro.sim.fleet.ClassBatch).  Returns the
        weighted FedAvg partial over this invocation's winners; partials
        across invocations just add (weights are global)."""
        self._note_shape(("class", class_x.shape, plans.shape))
        step = self._train_gather_sharded \
            if self._train_gather_sharded is not None \
            else self._train_gather
        return step(global_params, class_x, class_y, rows, plans,
                    step_mask, weights)

    def train_class_updates(self, global_params, class_x, class_y, rows,
                            plans, step_mask) -> jnp.ndarray:
        """(C_cap, D) float32 flat deltas for one capacity-class
        invocation — the device runtime's defended-path twin of
        :meth:`train_class`.  Always the single-device program (the
        screened reduction downstream is single-device; the replicated
        class store makes that correct on any mesh)."""
        if self._train_gather_updates is None:
            self._train_gather_updates = self._build_train_gather_updates()
        self._note_shape(("class_upd", class_x.shape, plans.shape))
        return self._train_gather_updates(global_params, class_x, class_y,
                                          rows, plans, step_mask)

    def weight_features(self, global_params, buckets: List[CohortBucket],
                        num_clients: int) -> jnp.ndarray:
        """(N, D) weight-delta features in original client order."""
        rows = [None] * num_clients
        for b in buckets:
            feats = self._weight_feats(global_params, b.xb, b.yb,
                                       b.step_mask)
            for row, cid in enumerate(b.client_idx):
                if cid >= 0:
                    rows[int(cid)] = feats[row]
        missing = [i for i, r in enumerate(rows) if r is None]
        if missing:
            raise ValueError(
                f"clients {missing} missing from the packed buckets: "
                f"expected every id in [0, {num_clients}) exactly once "
                "(zero-size clients are dropped by the packer and have no "
                "weight-delta feature)")
        return jnp.stack(rows)

    def gradient_features(self, params, xb, yb) -> jnp.ndarray:
        """(N, D) mean sample-window gradients; ``xb (N, T0, window,
        *feat)``, ``yb (N, T0, window)`` (uniform window — no buckets)."""
        return self._grad_feats(params, xb, yb)
