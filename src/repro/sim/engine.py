"""Vectorized cohort engine: one compiled program per bucket shape.

Three compiled entry points, all ``jax.vmap`` over the client axis with a
``jax.lax.scan`` over minibatch steps inside:

  * :meth:`CohortEngine.train_bucket` — the round's local training: every
    client runs ``local_epochs`` of SGD (optionally FedProx-proximal)
    from the shared global params; masked (padding) steps are the
    identity on both params and optimizer state; the bucket's weighted
    FedAvg partial sum is fused into the same program.
  * :meth:`CohortEngine.weight_features` — the Wang-et-al clustering
    feature: flattened param delta after one in-order epoch of plain SGD.
  * :meth:`CohortEngine.gradient_features` — the paper's clustering
    feature: mean flattened gradient over the T0 sample-window draws.

``jax.jit`` retraces per distinct bucket shape ``(C, S, bs)``; the packer
pads C to a multiple of the vmap chunk width, S to a multiple of 4, and
band-buckets step counts by power of two to keep that cache small.  The client axis is processed in ``cfg.cohort_vmap_width``-wide
vmap chunks under an outer ``jax.lax.map``: a full-width vmap multiplies
the per-op working set by C and thrashes the CPU cache (measured 1.4-2x
slower than the loop for the paper's CNNs), while narrow chunks keep
each op cache-resident and still amortize dispatch to one call per
bucket.  Equivalence with the sequential oracle is exact up to float
reassociation (tested in tests/test_sim.py).
"""
from __future__ import annotations

from typing import Any, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core.adapters import ModelAdapter
from repro.optim import apply_updates, fedprox_grad, sgd
from repro.sim.cohort import CohortBucket


def _flatten_tree(tree) -> jnp.ndarray:
    return jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(tree)])


def _chunk_width(c: int, width: int) -> int:
    """Largest power of two <= width that divides c."""
    w = 1
    while w * 2 <= min(width, c) and c % (w * 2) == 0:
        w *= 2
    return w


def _client_map(fn, args: Tuple[jnp.ndarray, ...], width: int):
    """Map ``fn`` over the leading client axis of every array in ``args``:
    vmap in ``width``-wide chunks under an outer ``lax.map`` (see module
    docstring for why not one full-width vmap)."""
    c = args[0].shape[0]
    w = _chunk_width(c, width)
    if w == c:
        return jax.vmap(fn)(*args)
    re = tuple(a.reshape((c // w, w) + a.shape[1:]) for a in args)
    chunks = jax.lax.map(lambda ch: jax.vmap(fn)(*ch), re)
    return jax.tree.map(lambda a: a.reshape((c,) + a.shape[2:]), chunks)


class CohortEngine:
    def __init__(self, adapter: ModelAdapter, cfg: FLConfig):
        self.adapter = adapter
        self.cfg = cfg
        self._train = self._build_train()      # jitted inside the builder
        self._weight_feats = jax.jit(self._build_weight_features())
        self._grad_feats = jax.jit(self._build_gradient_features())

    # ------------------------------------------------------------------
    def _local_scan(self, params0, opt_init, opt_update, xb, yb, mask,
                    global_params, proximal: bool):
        """Scan ``local_step`` over the step axis for one client."""

        def step(carry, inp):
            p, opt = carry
            xs, ys, m = inp
            g = self.adapter.grad(p, {"x": xs, "y": ys})
            if proximal:
                g = fedprox_grad(g, p, global_params, self.cfg.fedprox_mu)
            u, opt2 = opt_update(g, opt, p)
            p2 = apply_updates(p, u)
            keep = m > 0.5
            nxt = jax.tree.map(lambda a, b: jnp.where(keep, b, a),
                               (p, opt), (p2, opt2))
            return nxt, None

        (p, _), _ = jax.lax.scan(step, (params0, opt_init(params0)),
                                 (xb, yb, mask))
        return p

    def _build_train(self):
        cfg = self.cfg
        init, upd = sgd(cfg.lr, momentum=cfg.local_momentum)
        proximal = cfg.aggregator == "fedprox"

        def train(global_params, xb, yb, mask, weights,
                  return_stacked=False):
            def one_client(cx, cy, cm):
                return self._local_scan(global_params, init, upd, cx, cy,
                                        cm, global_params, proximal)

            stacked = _client_map(one_client, (xb, yb, mask),
                                  cfg.cohort_vmap_width)
            agg = jax.tree.map(
                lambda leaf: jnp.tensordot(
                    weights, leaf.astype(jnp.float32), axes=1
                ).astype(leaf.dtype),
                stacked)
            # only materialize the (C, ...) per-client trees as a jit
            # output when asked — the round loop needs just the aggregate
            return (stacked, agg) if return_stacked else agg

        return jax.jit(train, static_argnames="return_stacked")

    def _build_weight_features(self):
        cfg = self.cfg
        init, upd = sgd(cfg.lr)   # the feature pass uses plain SGD

        def features(global_params, xb, yb, mask):
            def one_client(cx, cy, cm):
                p = self._local_scan(global_params, init, upd, cx, cy, cm,
                                     global_params, proximal=False)
                delta = jax.tree.map(lambda a, b: a - b, p, global_params)
                return _flatten_tree(delta)

            return _client_map(one_client, (xb, yb, mask),
                               self.cfg.cohort_vmap_width)

        return features

    def _build_gradient_features(self):
        def features(params, xb, yb):
            def one_client(cx, cy):
                def body(_, inp):
                    xs, ys = inp
                    g = self.adapter.grad(params, {"x": xs, "y": ys})
                    return None, _flatten_tree(g)

                _, flats = jax.lax.scan(body, None, (cx, cy))
                return flats.mean(0)

            return _client_map(one_client, (xb, yb),
                               self.cfg.cohort_vmap_width)

        return features

    # ------------------------------------------------------------------
    def train_bucket(self, global_params, bucket: CohortBucket
                     ) -> Tuple[Any, Any]:
        """Returns (stacked per-client params with leading C axis,
        weighted partial aggregate sum_c w_c * params_c).  The stacked
        trees are for inspection/tests; the round loop uses
        :meth:`train_cohort`, which skips materializing them."""
        return self._train(global_params, bucket.xb, bucket.yb,
                           bucket.step_mask, bucket.weights,
                           return_stacked=True)

    def train_cohort(self, global_params, buckets: List[CohortBucket]):
        """Aggregated params over all buckets, or None for an empty
        cohort.  Weights are global, so bucket partials just add."""
        agg = None
        for b in buckets:
            part = self._train(global_params, b.xb, b.yb, b.step_mask,
                               b.weights)
            agg = part if agg is None else jax.tree.map(
                jnp.add, agg, part)
        return agg

    def weight_features(self, global_params, buckets: List[CohortBucket],
                        num_clients: int) -> jnp.ndarray:
        """(N, D) weight-delta features in original client order."""
        rows = [None] * num_clients
        for b in buckets:
            feats = self._weight_feats(global_params, b.xb, b.yb,
                                       b.step_mask)
            for row, cid in enumerate(b.client_idx):
                if cid >= 0:
                    rows[int(cid)] = feats[row]
        return jnp.stack(rows)

    def gradient_features(self, params, xb, yb) -> jnp.ndarray:
        """(N, D) mean sample-window gradients; ``xb (N, T0, window,
        *feat)``, ``yb (N, T0, window)`` (uniform window — no buckets)."""
        return self._grad_feats(params, xb, yb)
