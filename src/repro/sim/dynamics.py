"""Client dynamics: availability churn, stragglers and deadline misses.

The paper's auction assumes every winner trains to completion instantly,
but its whole premise is energy/compute heterogeneity in a mobile edge
fleet — real clients drop out, miss deadlines, and return stale updates
(FedCS, Nishio & Yonetani, arXiv:1804.08333).  This module is the
jittable per-round fault model the fused round control plane
(repro.core.rounds) composes into its compiled program when
``cfg.dynamics_enabled``:

  * **availability churn** — a two-state arrival/dropout Markov process
    per client: an available client drops with prob ``cfg.churn`` per
    round, an unavailable one rejoins with prob ``cfg.rejoin_prob``.
    Round-start availability gates auction *eligibility* (an offline
    client cannot bid); a winner that goes offline mid-round (another
    ``churn`` draw) is DROPPED.
  * **stragglers** — per-client compute+network latency sampled from the
    existing energy-heterogeneity profile: the compute term scales with
    the client's local sample count (the same ``Ns_i`` that drives eq 11
    energy) and a profile-dependent slowdown factor —
    ``energy`` (default) maps low residual energy to up to ~3x slowdown,
    ``uniform``/``lognormal`` are energy-independent noise, ``none`` is
    deterministic.  Latency is expressed in units of the fleet-mean
    round time, so ``cfg.deadline`` has a scale-free meaning.
  * **deadline misses** — à la FedCS: a surviving winner whose latency
    exceeds ``cfg.deadline`` (when positive) is LATE — its update still
    exists but arrives after the round closes (the buffered aggregation
    path folds it in later; the sync path loses it).

Everything is a pure function of ``(state, key)`` under a **dedicated
PRNG key stream** (:func:`dynamics_key`), disjoint from the server's
selection/init chain — that separation is what keeps ``--churn 0`` runs
bit-identical to the dynamics-free path (regression-tested in
tests/test_dynamics.py).

Outcome encoding (int32, per client): 0 = not selected this round,
1 = COMPLETED, 2 = LATE, 3 = DROPPED.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig

# per-winner outcome codes (see module docstring)
NOT_SELECTED = 0
COMPLETED = 1
LATE = 2
DROPPED = 3

STRAGGLER_PROFILES = ("energy", "uniform", "lognormal", "none")

# update-corruption attacks (repro.core.aggregation screens them); the
# last three are ADAPTIVE: they observe the defense's running state
# (the clip EMA / honest cohort statistics / the round counter) and
# shape their perturbation to slip under static thresholds
ATTACKS = ("none", "nan", "scale", "signflip", "noise",
           "sub_clip", "alie", "on_off")

# fold_in tag separating the dynamics chain from the selection chain
_DYN_STREAM_TAG = 0x5D7A11CE
# fold_in tag for the adversary/corruption chain: its own stream, so
# corruption composes with churn on OR off and neither ever perturbs
# the selection chain (--adversary-frac 0 stays bit-identical)
_ADV_STREAM_TAG = 0xAD5E11A7


@dataclass
class DynamicsState:
    """Carried fleet-dynamics state (pytree; flows through jit like
    SelectionState).  ``avail`` is the churn process's current
    availability mask."""

    avail: jnp.ndarray          # (N,) bool — client reachable this round


jax.tree_util.register_dataclass(
    DynamicsState, data_fields=["avail"], meta_fields=[])


def dynamics_key(cfg: FLConfig) -> jnp.ndarray:
    """Root of the DEDICATED dynamics key stream: folded off the run seed
    with a fixed tag so it never collides with (or consumes from) the
    server's selection/init split chain.  Runs with identical seeds but
    different dynamics settings therefore see identical selection keys."""
    return jax.random.fold_in(jax.random.PRNGKey(cfg.seed),
                              _DYN_STREAM_TAG)


def init_dynamics(cfg: FLConfig) -> DynamicsState:
    """Round-0 dynamics state: everyone starts available (the churn
    process mixes toward its stationary split within a few rounds)."""
    return DynamicsState(avail=jnp.ones((cfg.num_clients,), bool))


# ----------------------------------------------------------------------
# Byzantine corruption model (per-winner update perturbation)
# ----------------------------------------------------------------------

def adversary_key(cfg: FLConfig) -> jnp.ndarray:
    """Root of the DEDICATED adversary key stream (same construction as
    :func:`dynamics_key`, different tag): membership and per-round
    corruption draws are a pure function of the run seed, independent of
    both the selection chain and the dynamics chain."""
    return jax.random.fold_in(jax.random.PRNGKey(cfg.seed),
                              _ADV_STREAM_TAG)


def adversary_mask(cfg: FLConfig) -> jnp.ndarray:
    """(N,) bool — the run's fixed Byzantine set: exactly
    ``round(adversary_frac * N)`` clients drawn once from the adversary
    chain (a deterministic count, not per-client Bernoulli, so the
    benchmark's 0/0.1/0.3 fractions mean what they say)."""
    n = cfg.num_clients
    m = int(round(cfg.adversary_frac * n))
    if m <= 0:
        return jnp.zeros((n,), bool)
    perm = jax.random.permutation(jax.random.fold_in(adversary_key(cfg), 0),
                                  n)
    return jnp.zeros((n,), bool).at[perm[:m]].set(True)


def _honest_stats(deltas: jnp.ndarray, adv: jnp.ndarray,
                  valid: jnp.ndarray):
    """Colluding-adversary view of the cohort: mean, per-coordinate std
    and median l2 norm of the HONEST rows (the classic omniscient-
    adversary assumption — colluders pool their observations of the
    benign updates to shape an attack that blends in)."""
    ok = valid & ~adv
    okf = ok[:, None]
    cnt = jnp.maximum(ok.sum(), 1).astype(jnp.float32)
    mean = jnp.where(okf, deltas, 0.0).sum(axis=0) / cnt
    var = jnp.where(okf, jnp.square(deltas - mean), 0.0).sum(axis=0) / cnt
    std = jnp.sqrt(var)
    norms = jnp.sqrt(jnp.square(jnp.where(okf, deltas, 0.0)).sum(axis=1))
    sorted_n = jnp.sort(jnp.where(ok, norms, jnp.inf))
    v = ok.sum()
    idx = jnp.clip((0.5 * (v - 1).astype(jnp.float32)).astype(jnp.int32),
                   0, deltas.shape[0] - 1)
    med_norm = jnp.where(v > 0, jnp.take(sorted_n, idx), 0.0)
    return mean, std, med_norm


def corrupt_updates(cfg: FLConfig, key, deltas: jnp.ndarray,
                    adv: jnp.ndarray, valid: jnp.ndarray,
                    clip_ema=None, round_idx=None) -> jnp.ndarray:
    """Perturb the adversarial rows of a (C, D) flat param-delta matrix
    — the on-device, post-local-training corruption step.  Pure and
    jittable (``cfg`` is static); honest and padding rows pass through
    bit-unchanged.  Attacks (``cfg.attack``):

      * ``nan``      — NaN-poison the whole row (caught by quarantine);
      * ``scale``    — multiply by ``attack_scale`` (norm inflation —
        finite, so it must be *clipped or trimmed*, not quarantined);
      * ``signflip`` — multiply by ``-attack_scale`` (amplified
        gradient-ascent direction);
      * ``noise``    — add Gaussian noise with std ``attack_scale`` x
        the cohort's honest RMS delta magnitude.

    Adaptive attacks (they read the defense's running state — the fused
    screened program passes its ``clip_ema`` carry and the round index
    in, so threshold awareness costs no extra host sync):

      * ``sub_clip`` — colluders send the NEGATED honest mean direction
        scaled to ``sub_clip_margin x clip_mult x`` the clip EMA (the
        static clip threshold): maximal drag that a fixed-threshold clip
        never touches.  Unseeded EMA (round 0) falls back to the honest
        median norm the EMA is about to seed on.
      * ``alie``     — "a little is enough"-style collusion: rows move
        to honest mean minus ``alie_z x`` the per-coordinate honest
        std — inside the trimmed-mean band for small z.
      * ``on_off``   — alternates ``onoff_period`` dirty rounds (the
        ``scale`` attack) with as many clean ones, farming decayed
        reputation back between bursts.
    """
    a = cfg.attack
    if a == "none" or not cfg.adversary_enabled:
        return deltas
    hit = (adv & valid)[:, None]
    if a == "nan":
        return jnp.where(hit, jnp.float32(jnp.nan), deltas)
    if a == "scale":
        return jnp.where(hit, cfg.attack_scale * deltas, deltas)
    if a == "signflip":
        return jnp.where(hit, -cfg.attack_scale * deltas, deltas)
    if a == "noise":
        ok = valid[:, None]
        denom = jnp.maximum(valid.sum() * deltas.shape[1], 1)
        rms = jnp.sqrt(jnp.square(
            jnp.where(ok, deltas, 0.0).astype(jnp.float32)).sum() / denom)
        noise = (jax.random.normal(key, deltas.shape, deltas.dtype)
                 * cfg.attack_scale * rms)
        return jnp.where(hit, deltas + noise, deltas)
    if a == "sub_clip":
        mean, _, med_norm = _honest_stats(deltas, adv, valid)
        ce = jnp.float32(0.0) if clip_ema is None else clip_ema
        base = jnp.where(ce > 0, ce, med_norm)
        target = cfg.sub_clip_margin * cfg.clip_mult * base
        mnorm = jnp.sqrt(jnp.square(mean).sum())
        row = -mean / jnp.maximum(mnorm, 1e-12) * target
        return jnp.where(hit, row[None, :], deltas)
    if a == "alie":
        mean, std, _ = _honest_stats(deltas, adv, valid)
        row = mean - cfg.alie_z * std
        return jnp.where(hit, row[None, :], deltas)
    if a == "on_off":
        period = max(int(cfg.onoff_period), 1)
        r = jnp.int32(0) if round_idx is None \
            else jnp.asarray(round_idx, jnp.int32)
        active = (r // period) % 2 == 0
        return jnp.where(hit & active, cfg.attack_scale * deltas, deltas)
    raise ValueError(f"unknown attack={a!r}; expected {ATTACKS}")


# ----------------------------------------------------------------------
# latency model
# ----------------------------------------------------------------------

def latency_scale(cfg: FLConfig, key, residual: jnp.ndarray) -> jnp.ndarray:
    """Per-client slowdown factor under ``cfg.straggler_profile``.

    ``energy`` ties the factor to the SAME heterogeneity profile the
    auction's cost function already prices: a full battery runs at 1x, an
    empty one at ~3x (edge devices throttle compute as charge drops), plus
    a small jittered component so equal-energy clients still diverge.
    """
    p = cfg.straggler_profile
    if p == "none":
        return jnp.ones_like(residual)
    if p == "uniform":
        return jax.random.uniform(key, residual.shape, minval=0.5,
                                  maxval=2.0)
    if p == "lognormal":
        return jnp.exp(0.5 * jax.random.normal(key, residual.shape))
    if p == "energy":
        frac = jnp.clip(residual / 100.0, 0.0, 1.0)
        jitter = jax.random.uniform(key, residual.shape, minval=0.9,
                                    maxval=1.1)
        return (1.0 + 2.0 * (1.0 - frac)) * jitter
    raise ValueError(f"unknown straggler_profile={p!r}; "
                     f"expected {STRAGGLER_PROFILES}")


def round_latency(cfg: FLConfig, key, residual: jnp.ndarray,
                  local_sizes: jnp.ndarray) -> jnp.ndarray:
    """Per-client compute+network latency in units of the fleet-mean
    round time: compute scales with the local sample count (eq 11's
    ``Ns_i``) times the straggler factor; the additive term is the
    (size-independent) model up/download."""
    sizes = local_sizes.astype(jnp.float32)
    compute = sizes / jnp.maximum(sizes.mean(), 1.0)
    return compute * latency_scale(cfg, key, residual) + 0.05


# ----------------------------------------------------------------------
# the per-round fault step
# ----------------------------------------------------------------------

def fault_step(cfg: FLConfig, key, win: jnp.ndarray, avail: jnp.ndarray,
               residual: jnp.ndarray, local_sizes: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One round of the fault model.  Pure and jittable — the fused round
    body calls this inside its compiled program; tests call it standalone.

    Args: ``win`` (N,) bool auction winners, ``avail`` (N,) bool
    round-start availability, ``residual``/``local_sizes`` the
    SelectionState columns the latency model reads.

    Returns ``(outcome, latency, new_avail)``: (N,) int32 outcome codes
    (NOT_SELECTED for non-winners), (N,) float32 latencies, and the next
    round's availability mask (winners that dropped mid-round start the
    next round offline; non-winners churn independently).
    """
    k_mid, k_lat, k_drop, k_join = jax.random.split(key, 4)
    lat = round_latency(cfg, k_lat, residual, local_sizes)

    # mid-round dropout: a second churn draw — being selected doesn't
    # shield a client from losing connectivity while it trains
    mid_drop = jax.random.bernoulli(k_mid, cfg.churn, win.shape)
    survived = win & avail & ~mid_drop
    missed = (cfg.deadline > 0.0) & (lat > cfg.deadline)
    outcome = jnp.where(
        win,
        jnp.where(survived,
                  jnp.where(missed, LATE, COMPLETED),
                  DROPPED),
        NOT_SELECTED).astype(jnp.int32)

    # availability churn for the next round (arrival/dropout process);
    # mid-round droppers are offline regardless of their churn draw
    drop = jax.random.bernoulli(k_drop, cfg.churn, avail.shape)
    join = jax.random.bernoulli(k_join, cfg.rejoin_prob, avail.shape)
    new_avail = jnp.where(avail, ~drop, join) & ~(win & mid_drop)
    return outcome, lat, new_avail


def update_staleness(staleness: jnp.ndarray,
                     outcome: jnp.ndarray) -> jnp.ndarray:
    """The SelectionState staleness counter: rounds since a client last
    COMPLETED a round (its view of the global model ages by one round
    unless its update landed synchronously this round)."""
    return jnp.where(outcome == COMPLETED, 0,
                     staleness + 1).astype(jnp.int32)


def outcome_metrics(outcome: jnp.ndarray,
                    staleness: jnp.ndarray) -> dict:
    """On-device per-round dynamics scalars for the fused metrics dict
    (fetched with the round's one batched drain — no extra sync)."""
    return {
        "num_completed": (outcome == COMPLETED).sum(),
        "num_late": (outcome == LATE).sum(),
        "num_dropped": (outcome == DROPPED).sum(),
        "staleness_mean": staleness.astype(jnp.float32).mean(),
        "staleness_max": staleness.max(),
    }


# ----------------------------------------------------------------------
# host-side helpers (server aggregation path)
# ----------------------------------------------------------------------

def split_outcomes(sel_idx: np.ndarray, outcome_np: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Partition the fetched winner indices by outcome code:
    ``(completed, late, dropped)`` — the server trains ``completed``
    (plus replacements) synchronously, routes ``late`` to the buffered
    path, and resamples ``dropped``."""
    codes = outcome_np[sel_idx]
    return (sel_idx[codes == COMPLETED], sel_idx[codes == LATE],
            sel_idx[codes == DROPPED])


def staleness_weight(cfg: FLConfig, tau: int) -> float:
    """FedBuff-style staleness discount for a buffered update folded
    ``tau`` rounds after its dispatch: ``(1 + tau) ** -alpha``."""
    return float((1.0 + float(tau)) ** -cfg.staleness_alpha)
