"""Cohort execution engine: batched local training for whole auction
cohorts (see DESIGN.md §Cohort-engine and ROADMAP.md §Usage).

  * cohort.py  — packs selected clients' shards into padded, size-bucketed
    minibatch tensors with per-step validity masks.
  * engine.py  — runs local SGD/FedProx epochs for a whole bucket as one
    compiled program: ``jax.vmap`` over clients, ``jax.lax.scan`` over
    minibatch steps, fused weighted aggregation.
  * runtime.py — the ``CohortRuntime`` protocol and the three backends
    (``sequential`` reference oracle, ``vectorized`` engine, ``sharded``
    mesh-mapped engine).
"""
from repro.sim.cohort import CohortBucket, pack_cohort, pack_feature_pass
from repro.sim.engine import CohortEngine
from repro.sim.runtime import (CohortRuntime, SequentialRuntime,
                               ShardedRuntime, VectorizedRuntime,
                               make_runtime)

__all__ = [
    "CohortBucket", "pack_cohort", "pack_feature_pass",
    "CohortEngine",
    "CohortRuntime", "SequentialRuntime", "ShardedRuntime",
    "VectorizedRuntime", "make_runtime",
]
