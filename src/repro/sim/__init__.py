"""Cohort execution engine: batched local training for whole auction
cohorts (see DESIGN.md §Cohort-engine / §Round pipeline and ROADMAP.md
§Usage).

  * cohort.py  — packs selected clients' shards into padded, size-bucketed
    minibatch tensors with per-step validity masks; ``HostPlanCache``
    memoizes the per-client plan structure + local data gathers.
  * fleet.py   — ``FleetStore``: the whole fleet packed once into
    device-resident capacity-class tensors; per-round cohorts assemble as
    tiny int index plans (the ``device`` runtime's data plane).
  * engine.py  — runs local SGD/FedProx epochs for a whole bucket or
    capacity class as one compiled program: ``jax.vmap`` over clients,
    ``jax.lax.scan`` over minibatch steps, fused weighted aggregation.
  * runtime.py — the ``CohortRuntime`` protocol and the four backends
    (``sequential`` reference oracle, ``vectorized`` engine, ``sharded``
    mesh-mapped engine, ``device`` resident-fleet pipeline).
  * dynamics.py — the jittable per-round fault model (availability
    churn, stragglers, FedCS-style deadline misses) the fused round
    control plane composes in when ``cfg.dynamics_enabled``.
"""
from repro.sim.cohort import (CohortBucket, HostPlanCache, pack_cohort,
                              pack_feature_pass)
from repro.sim.dynamics import (DynamicsState, dynamics_key, fault_step,
                                init_dynamics, split_outcomes)
from repro.sim.engine import CohortEngine
from repro.sim.fleet import CapacityClass, ClassBatch, FleetStore
from repro.sim.runtime import (CohortRuntime, DeviceRuntime,
                               SequentialRuntime, ShardedRuntime,
                               VectorizedRuntime, make_runtime)

__all__ = [
    "CohortBucket", "HostPlanCache", "pack_cohort", "pack_feature_pass",
    "CohortEngine",
    "CapacityClass", "ClassBatch", "FleetStore",
    "CohortRuntime", "DeviceRuntime", "SequentialRuntime",
    "ShardedRuntime", "VectorizedRuntime", "make_runtime",
    "DynamicsState", "dynamics_key", "fault_step", "init_dynamics",
    "split_outcomes",
]
