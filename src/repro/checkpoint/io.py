"""Pytree checkpointing (no orbax offline): flatten a pytree to a .npz with
path-encoded keys + a JSON manifest for dtypes/tree structure. Works for
model params, optimizer state, and FL server state.
"""
from __future__ import annotations

import json
import os
import warnings
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if key in flat:
            # two distinct leaves stringifying to one key would silently
            # drop the first on save and restore garbage into both
            raise ValueError(
                f"duplicate flattened checkpoint key {key!r}: the tree "
                "has two leaves whose paths stringify identically")
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:   # npz can't store ml_dtypes natively
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save(path: str, tree, step: int = 0, extra: Dict[str, Any] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "keys": list(flat.keys()),
        "extra": extra or {},
    }
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    with open(path.removesuffix(".npz") + ".json", "w") as f:
        json.dump(manifest, f, indent=1)


def restore(path: str, like) -> Tuple[Any, int]:
    """Restore into the structure of `like` (a pytree of arrays or shapes)."""
    base = path.removesuffix(".npz")
    data = np.load(base + ".npz")
    with open(base + ".json") as f:
        manifest = json.load(f)
    flat_like = _flatten(like)
    assert set(flat_like) == set(data.files), (
        f"checkpoint keys mismatch: {set(flat_like) ^ set(data.files)}")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    stored_td = manifest.get("treedef")
    if stored_td is not None and stored_td != str(treedef):
        # the key SET matching while the structure string differs means
        # containers changed shape (e.g. a dataclass grew a field that
        # flattens to nothing, or dict/list nesting moved) — restoring by
        # key still works, but the caller should know the layouts drifted
        warnings.warn(
            "checkpoint treedef mismatch: stored structure differs from "
            f"the restore target ({stored_td!r} != {str(treedef)!r}); "
            "leaves are matched by flattened key", stacklevel=2)
    paths = [
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    new_leaves = [jnp.asarray(data[k]).astype(l.dtype)
                  for k, l in zip(paths, leaves_like)]
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["step"]
