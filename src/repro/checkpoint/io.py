"""Pytree checkpointing (no orbax offline): flatten a pytree to a .npz with
path-encoded keys + a JSON manifest for dtypes/tree structure. Works for
model params, optimizer state, and FL server state.

Writes are crash-safe: both the .npz and the manifest land in temp files
first and are moved into place with ``os.replace`` (atomic on POSIX), and
the manifest records a sha256 digest of the snapshot so a truncated or
bit-rotted .npz fails :func:`restore` with :class:`CheckpointCorrupt`
instead of a raw unpickling traceback — the watchdog's checkpoint ring
relies on that to fall back to the next-older entry.
"""
from __future__ import annotations

import hashlib
import json
import os
import warnings
import zipfile
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointCorrupt(RuntimeError):
    """A snapshot on disk is unreadable or fails its integrity check
    (truncated write, bit rot, or a manifest/npz digest mismatch)."""


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if key in flat:
            # two distinct leaves stringifying to one key would silently
            # drop the first on save and restore garbage into both
            raise ValueError(
                f"duplicate flattened checkpoint key {key!r}: the tree "
                "has two leaves whose paths stringify identically")
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:   # npz can't store ml_dtypes natively
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _digest(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save(path: str, tree, step: int = 0, extra: Dict[str, Any] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    treedef = jax.tree_util.tree_structure(tree)
    npz_path = path if path.endswith(".npz") else path + ".npz"
    # temp-write + os.replace so a crash mid-save never leaves a torn
    # snapshot under the real name (the tmp name is pid-scoped so two
    # processes checkpointing the same path can't collide mid-write)
    tmp_npz = npz_path + f".tmp{os.getpid()}"
    with open(tmp_npz, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp_npz, npz_path)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "keys": list(flat.keys()),
        "digest": _digest(npz_path),
        "extra": extra or {},
    }
    json_path = path.removesuffix(".npz") + ".json"
    tmp_json = json_path + f".tmp{os.getpid()}"
    with open(tmp_json, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp_json, json_path)


def restore(path: str, like) -> Tuple[Any, int]:
    """Restore into the structure of `like` (a pytree of arrays or shapes).

    Raises :class:`CheckpointCorrupt` when the manifest is unparseable,
    the .npz digest doesn't match the manifest's recorded digest, or the
    .npz itself fails to load."""
    base = path.removesuffix(".npz")
    try:
        with open(base + ".json") as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CheckpointCorrupt(
            f"checkpoint manifest {base + '.json'} is unreadable: {e}"
        ) from e
    stored_digest = manifest.get("digest")
    if stored_digest is not None and _digest(base + ".npz") != stored_digest:
        raise CheckpointCorrupt(
            f"checkpoint {base + '.npz'} fails its integrity check: "
            "content digest does not match the manifest (truncated or "
            "corrupted snapshot)")
    try:
        data = np.load(base + ".npz")
        files = set(data.files)
    except (zipfile.BadZipFile, OSError, ValueError) as e:
        raise CheckpointCorrupt(
            f"checkpoint {base + '.npz'} is unreadable: {e}") from e
    flat_like = _flatten(like)
    assert set(flat_like) == files, (
        f"checkpoint keys mismatch: {set(flat_like) ^ files}")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    stored_td = manifest.get("treedef")
    if stored_td is not None and stored_td != str(treedef):
        # the key SET matching while the structure string differs means
        # containers changed shape (e.g. a dataclass grew a field that
        # flattens to nothing, or dict/list nesting moved) — restoring by
        # key still works, but the caller should know the layouts drifted
        warnings.warn(
            "checkpoint treedef mismatch: stored structure differs from "
            f"the restore target ({stored_td!r} != {str(treedef)!r}); "
            "leaves are matched by flattened key", stacklevel=2)
    paths = [
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    new_leaves = [jnp.asarray(data[k]).astype(l.dtype)
                  for k, l in zip(paths, leaves_like)]
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["step"]
