"""Per-round client selection (Algorithm 1, lines 10-25) for all four
schemes compared in the paper:

  * gradient_cluster_auction — the paper's full scheme: per-cluster reverse
    auction with Nash-equilibrium bids and the s_min sample threshold.
  * gradient_cluster_random — the paper's clustering with random in-cluster
    picks (plus the §III-C sample threshold).
  * weights_cluster_random  — Wang et al. [2] baseline: clusters from local
    model-weight features, random in-cluster picks.
  * random                  — FedAvg/FedProx random-K selection.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import auction as A
from repro.core import energy as E


@dataclass
class SelectionState:
    """Struct-of-arrays client state used by the selector.

    Registered as a pytree (all fields are array leaves) so whole states
    flow through jit / lax.scan — the fused round control plane
    (repro.core.rounds) carries a SelectionState across rounds on device.
    """

    clusters: jnp.ndarray        # (N,) int32 cluster id (0 for 'random')
    residual: jnp.ndarray        # (N,) float32 energy percent
    history: jnp.ndarray         # (N,) int32 participation rounds so far
    local_sizes: jnp.ndarray     # (N,) int32 |xi_k|
    # (N,) int32 rounds since the client last completed a round, or None
    # when fleet dynamics are off (None is an empty pytree node, so the
    # dynamics-free round programs trace exactly as before — the churn-0
    # bit-identity regression depends on this)
    staleness: Optional[jnp.ndarray] = None
    # (N,) float32 quarantine-strike reputation counter, or None when the
    # defended aggregation path is off (same Optional-last-field pattern
    # as staleness — the defense-off bit-identity regression depends on
    # it).  The screened aggregation scatter-adds a strike per quarantined
    # update; a client at >= cfg.strike_threshold strikes loses auction
    # eligibility until per-round decay (update_after_round) re-admits it.
    strikes: Optional[jnp.ndarray] = None
    # per-scheme carried state (repro.core.schemes), or None for
    # stateless schemes — the third instance of the Optional-last-field
    # pattern: a None scheme_state is an empty pytree node, so every
    # scheme that doesn't thread state (paper, random, fedcs) traces the
    # exact pre-registry round programs.  The long-term auction carries
    # its budget/payment ledger here so it flows through jit / lax.scan
    # / checkpoints with the rest of the state.
    scheme_state: Optional[object] = None


jax.tree_util.register_dataclass(
    SelectionState,
    data_fields=["clusters", "residual", "history", "local_sizes",
                 "staleness", "strikes", "scheme_state"],
    meta_fields=[])


def k_per_cluster(cfg: FLConfig) -> int:
    k_total = max(int(round(cfg.select_ratio * cfg.num_clients)), 1)
    return max(k_total // cfg.num_clusters, 1)


def _sample_threshold(key, state: SelectionState, cfg: FLConfig,
                      bids: jnp.ndarray | None) -> jnp.ndarray:
    """s_min: server picks a random cluster js; among its K_j lowest bidders
    (auction) or a random member (random schemes), take the minimum local
    size. Gates auction entry so selected data sizes stay at one level."""
    kj = k_per_cluster(cfg)
    js = jax.random.randint(key, (), 0, cfg.num_clusters)
    in_js = state.clusters == js
    if bids is not None:
        win_js = A.select_lowest_bids(
            jnp.where(in_js, bids, A.INF), in_js, kj)
        sizes = jnp.where(win_js, state.local_sizes, jnp.int32(2 ** 30))
        smin = sizes.min()
        # fall back to 0 if the probe cluster is empty
        return jnp.where(win_js.any(), smin, 0)
    # random schemes: one random client's size (paper §III-C)
    probs = in_js / jnp.maximum(in_js.sum(), 1)
    pick = jax.random.choice(jax.random.fold_in(key, 1),
                             state.clusters.shape[0], p=probs)
    return jnp.where(in_js.any(), state.local_sizes[pick], 0)


def _random_per_cluster(key, state: SelectionState, cfg: FLConfig,
                        eligible: jnp.ndarray) -> jnp.ndarray:
    """K_j uniform picks per cluster among eligible clients: one segmented
    rank pass (lexsort by (cluster, noise) + per-segment offsets) instead
    of an argsort per cluster — same winner sets as the per-cluster loop
    oracle below under a fixed key (regression-tested)."""
    kj = k_per_cluster(cfg)
    n = state.clusters.shape[0]
    nj = cfg.num_clusters
    cl = state.clusters
    noise = jax.random.uniform(key, (n,))
    # clusters with no eligible member relax to their whole membership
    has_elig = jnp.zeros((nj,), jnp.int32).at[cl].max(
        eligible.astype(jnp.int32))
    e = jnp.where(has_elig[cl] > 0, eligible, True)
    keyed = jnp.where(e, noise, 2.0)     # ineligible sort after all noise
    order = jnp.lexsort((keyed, cl))     # cluster-major, noise-minor
    rank_in_cluster = A.segment_ranks(order, cl, nj)
    win_sorted = (rank_in_cluster < kj) & e[order]
    return jnp.zeros((n,), bool).at[order].set(win_sorted)


def _random_per_cluster_loop(key, state: SelectionState, cfg: FLConfig,
                             eligible: jnp.ndarray) -> jnp.ndarray:
    """Reference oracle for :func:`_random_per_cluster`: the seed
    implementation's Python loop over clusters (one argsort each)."""
    kj = k_per_cluster(cfg)
    n = state.clusters.shape[0]
    noise = jax.random.uniform(key, (n,))
    win = jnp.zeros((n,), bool)
    for j in range(cfg.num_clusters):
        in_j = (state.clusters == j) & eligible
        # if nothing is eligible in cluster j, relax to the whole cluster
        in_j = jnp.where(in_j.any(), in_j, state.clusters == j)
        keyed = jnp.where(in_j, noise, 2.0)
        order = jnp.argsort(keyed)
        ranks = jnp.zeros_like(order).at[order].set(jnp.arange(n))
        win = win | ((ranks < kj) & in_j)
    return win


def select_round(state: SelectionState, cfg: FLConfig, key,
                 winners_impl: str = "segmented",
                 avail: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Run one round of selection. Returns (winner mask (N,) bool, info).
    ``winners_impl`` picks the per-cluster auction implementation
    (auction.cluster_winners): ``segmented`` fused top-k (default) or
    ``loop``, the seed per-cluster argsort oracle — bit-identical winner
    sets, kept selectable for regression tests and as the benchmark
    baseline.

    ``avail`` (fleet dynamics): round-start availability mask — an
    offline client cannot bid, so it joins the clustered schemes'
    eligibility conjunction; the pure ``random`` baseline keeps drawing
    blind (its picks model a server with no liveness signal — offline
    picks become DROPPED outcomes downstream).  ``None`` (the default)
    leaves every traced graph untouched."""
    n = cfg.num_clients
    k_total = max(int(round(cfg.select_ratio * n)), 1)
    keys = jax.random.split(key, 4)
    info: Dict[str, jnp.ndarray] = {}

    if cfg.scheme == "random":
        idx = jax.random.choice(keys[0], n, (k_total,), replace=False)
        win = jnp.zeros((n,), bool).at[idx].set(True)
        info["bids"] = jnp.zeros((n,))
        return win, info

    if cfg.scheme in ("gradient_cluster_random", "weights_cluster_random"):
        smin = _sample_threshold(keys[0], state, cfg, None)
        eligible = state.local_sizes >= smin
        if avail is not None:
            eligible = eligible & avail
        win = _random_per_cluster(keys[1], state, cfg, eligible)
        info["bids"] = jnp.zeros((n,))
        info["s_min"] = smin
        return win, info

    # ---- gradient_cluster_auction (the paper's scheme) ----
    kj = k_per_cluster(cfg)
    c, bids = A.price_round(state.clusters, state.residual,
                            state.local_sizes, state.history, kj, cfg)
    # step 1: probe cluster js fixes the sample threshold
    smin = _sample_threshold(keys[0], state, cfg, bids)
    eligible = (state.local_sizes >= smin) & (c < A.INF)
    if avail is not None:
        eligible = eligible & avail
    # step 2: per-cluster reverse auction among eligible clients.
    # Reputation pricing (--reputation-mode price) inflates a tainted
    # client's bid at the RANKING step only; eligibility, the threshold
    # probe, and payment stay on the true bids.  With pricing off,
    # effective_bids returns `bids` itself — identical trace.
    cs = A.service_cost(state.local_sizes, state.history, cfg)
    win = A.cluster_winners(A.effective_bids(bids, state.strikes, cfg),
                            state.clusters, eligible, kj,
                            cfg.num_clusters, tie_break=cs,
                            impl=winners_impl)
    info.update(bids=bids, costs=c, s_min=smin,
                revenue=A.revenue(bids, c, win))
    return win, info


def update_after_round(state: SelectionState, win: jnp.ndarray,
                       cfg: FLConfig) -> SelectionState:
    new = replace(
        state,
        residual=E.apply_round(state.residual, win, state.local_sizes, cfg),
        history=state.history + win.astype(jnp.int32),
    )
    if state.strikes is not None:
        # reputation decays once per round: a banned repeat offender
        # eventually falls back under the threshold and gets re-probed
        new = replace(new, strikes=state.strikes * cfg.strike_decay)
    return new
