"""Auction mechanism (paper §IV): cost function, Nash-equilibrium bids,
winner selection and reward models. Fully vectorized over clients.

Roles: the aggregation server is the *auctioneer*; edge clients are
*bidders* selling data + compute service. Within each cluster the K_j
lowest bids win (reverse auction).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core import energy as E

INF = jnp.float32(1e9)


# ----------------------------------------------------------------------
# cost function (eq 12-14)
# ----------------------------------------------------------------------

def resource_cost(residual: jnp.ndarray, e_cp: jnp.ndarray,
                  cfg: FLConfig) -> jnp.ndarray:
    """Cr_{i,t} = phi^(E_res - E_cp) if the client can afford the round,
    else +inf (eq 12). Rises toward 1 as the battery approaches depletion.

    The exponent is taken on the battery *fraction* (E in [0,1]): with the
    percent scale the paper's Table-I phi=0.5 would give phi^100 ~ 8e-31 and
    the resource cost would be identically zero for every healthy client —
    degenerate. On the fraction scale Cr spans [phi, 1), monotone in drain,
    exactly the behaviour eq 12 describes. (Recorded in DESIGN.md.)
    """
    margin = (residual - e_cp) / 100.0
    cr = jnp.power(cfg.phi, margin)
    return jnp.where(margin > 0, cr, INF)


def service_cost(local_sizes: jnp.ndarray, history: jnp.ndarray,
                 cfg: FLConfig) -> jnp.ndarray:
    """Cs_{i,t} = chi * vartheta^Ns + zeta * (log_a(co + a) - 1)  (eq 13*).

    (*) Sign note, recorded in DESIGN.md §Paper-deviations: eq 13 as printed
    is ``zeta * (1 - log_a(co + a))``, which *decreases* the cost of
    frequently-selected clients — the opposite of the paper's stated intent
    ("with the increase of clients' participation rounds, our model
    appropriately reduces service quality") and of its Fig 9/10 results
    (energy balance improves vs random). Empirically the verbatim sign makes
    the auction *worse*-balanced than random selection (rich-get-richer);
    with the intended sign the Fig 9/10 behaviour reproduces. We default to
    the intended sign; ``cfg.history_verbatim=True`` restores the printed
    formula.
    """
    sample_term = jnp.power(cfg.vartheta, local_sizes.astype(jnp.float32))
    hist = jnp.log(history.astype(jnp.float32) + cfg.log_a) \
        / jnp.log(cfg.log_a)
    sign = -1.0 if cfg.history_verbatim else 1.0
    return cfg.chi * sample_term + cfg.zeta * sign * (hist - 1.0)


def cost(residual, local_sizes, history, cfg: FLConfig) -> jnp.ndarray:
    """c_{i,t} = alpha*Cs + gamma*Cr (eq 14), clipped into the bid domain
    [0,1] (the Nash analysis assumes bids on [0,1]); +inf (can't afford)
    stays +inf."""
    e_cp = E.compute_cost_energy(local_sizes, cfg)
    cr = resource_cost(residual, e_cp, cfg)
    cs = service_cost(local_sizes, history, cfg)
    c = cfg.alpha * cs + cfg.gamma * cr
    return jnp.where(cr >= INF, INF, jnp.clip(c, 0.0, 1.0))


# ----------------------------------------------------------------------
# optimal bid (Theorem 2)
# ----------------------------------------------------------------------

def optimal_bid(c: jnp.ndarray, n_j, k_j) -> jnp.ndarray:
    """b* = 1/(N_j-K_j+1) + (N_j-K_j)/(N_j-K_j+1) * c  — the symmetric
    Nash-equilibrium bid of Theorem 2. n_j/k_j may be scalars or per-client
    arrays (cluster-dependent)."""
    n_j = jnp.asarray(n_j, jnp.float32)
    k_j = jnp.asarray(k_j, jnp.float32)
    d = jnp.maximum(n_j - k_j, 0.0)
    bid = 1.0 / (d + 1.0) + d / (d + 1.0) * c
    return jnp.where(c >= INF, INF, bid)


def revenue(bid: jnp.ndarray, c: jnp.ndarray,
            won: jnp.ndarray) -> jnp.ndarray:
    """U_i = b - c if the client wins else 0 (eq 18)."""
    return jnp.where(won, bid - c, 0.0)


def price_round(clusters: jnp.ndarray, residual: jnp.ndarray,
                local_sizes: jnp.ndarray, history: jnp.ndarray,
                k_j: int, cfg: FLConfig
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The PRICING half of one auction round: per-client cost (eq 14)
    and symmetric Nash bids (Theorem 2) under the current cluster sizes.
    Returns ``(cost, bids)``.

    Split out of selection.select_round so selection schemes
    (repro.core.schemes) compose pricing with their own winner-pick and
    eligibility rules — fedcs reprices then gates on predicted latency,
    the long-term auction reprices then gates on its budget ledger.  The
    op sequence is exactly the one select_round inlined, so the paper
    scheme's traces are unchanged."""
    nj = jnp.zeros((cfg.num_clusters,), jnp.float32).at[clusters].add(1.0)
    n_of = nj[clusters]                       # N_j per client
    c = cost(residual, local_sizes, history, cfg)
    bids = optimal_bid(c, n_of, float(k_j))
    return c, bids


def effective_bids(bids: jnp.ndarray, strikes, cfg: FLConfig) -> jnp.ndarray:
    """Reputation-priced bid: a tainted client competes at an inflated
    price ``b * (1 + gain * strikes)`` so it must underbid to win back
    trust, instead of being hard-banned at a strike threshold.

    Applied ONLY at the winner-ranking step — eligibility gates, the
    paper's sampling-threshold probe, and payment all stay on the TRUE
    bids (the platform prices risk, it does not rewrite the contract).
    When reputation pricing is off (ban mode, or no strikes tracked)
    this returns ``bids`` itself — the SAME traced object, so defended
    ban-mode traces match PR 8 bit-exactly."""
    if strikes is None or cfg.reputation_mode != "price":
        return bids
    return jnp.where(bids >= INF, INF,
                     bids * (1.0 + cfg.rep_price_gain * strikes))


# ----------------------------------------------------------------------
# winner selection
# ----------------------------------------------------------------------

def segment_ranks(order: jnp.ndarray, clusters: jnp.ndarray,
                  num_clusters: int) -> jnp.ndarray:
    """Within-cluster rank of each position of a cluster-major sort
    ``order``: segment sizes -> cumsum start offsets -> position minus the
    segment start. Shared by :func:`cluster_winners` and
    selection._random_per_cluster."""
    sizes = jnp.zeros((num_clusters,), jnp.int32).at[clusters].add(1)
    starts = jnp.cumsum(sizes) - sizes
    return jnp.arange(order.shape[0]) - starts[clusters[order]]


def select_lowest_bids(bids: jnp.ndarray, eligible: jnp.ndarray, k: int,
                       tie_break: jnp.ndarray | None = None
                       ) -> jnp.ndarray:
    """Boolean winner mask: k lowest eligible bids. Ties broken by the paper's
    rule (service cost then resource cost) via a true lexicographic sort —
    bids are the primary key, ``tie_break`` the secondary. An additive
    ``eps * tie_break`` composite key would *reorder* distinct bids closer
    than eps; lexsort only consults the tie-break on exactly-equal bids."""
    n = bids.shape[0]
    key = jnp.where(eligible, bids, INF)
    if tie_break is None:
        # lax.top_k prefers the lower index on equal values — identical
        # winner sets to a stable ascending argsort, at a fraction of the
        # cost (partial selection, not a full sort: ~40-80x on XLA CPU).
        vals, idx = jax.lax.top_k(-key, min(k, n))
        return jnp.zeros((n,), bool).at[idx].set(vals > -INF)
    order = jnp.lexsort((jnp.clip(tie_break, 0.0, 1.0), key))
    ranks = jnp.zeros_like(order).at[order].set(jnp.arange(n))
    return (ranks < k) & eligible & (key < INF)


def cluster_winners(bids: jnp.ndarray, clusters: jnp.ndarray,
                    eligible: jnp.ndarray, k_per_cluster: int,
                    num_clusters: int,
                    tie_break: jnp.ndarray | None = None,
                    impl: str = "segmented") -> jnp.ndarray:
    """Winner mask over all clients: K_j lowest eligible bids per cluster,
    ties broken lexicographically by ``tie_break`` then client index.

    ``segmented`` (default): ONE lexsort by (cluster, bid, tie-break)
    plus per-segment rank offsets (same trick as
    selection._random_per_cluster) — O(N log N) total instead of the
    loop's O(J · N log N), and a single fusable program for jit/scan use
    (measured ~6-8x over the loop at N=10k-1M, J=10 on a CPU dev box).
    ``impl="loop"`` routes to :func:`cluster_winners_loop`, the seed
    implementation kept as the regression oracle; both sorts are stable,
    so winner sets are bit-identical (tests/test_rounds.py)."""
    if impl == "loop":
        return cluster_winners_loop(bids, clusters, eligible, k_per_cluster,
                                    num_clusters, tie_break)
    assert impl == "segmented", impl
    n = bids.shape[0]
    key = jnp.where(eligible, bids, INF)
    tb = (jnp.zeros_like(key) if tie_break is None
          else jnp.clip(tie_break, 0.0, 1.0))
    order = jnp.lexsort((tb, key, clusters))   # cluster-major, bid, tie
    rank_in_cluster = segment_ranks(order, clusters, num_clusters)
    win_sorted = ((rank_in_cluster < k_per_cluster) & eligible[order]
                  & (key[order] < INF))
    return jnp.zeros((n,), bool).at[order].set(win_sorted)


def cluster_winners_loop(bids: jnp.ndarray, clusters: jnp.ndarray,
                         eligible: jnp.ndarray, k_per_cluster: int,
                         num_clusters: int,
                         tie_break: jnp.ndarray | None = None) -> jnp.ndarray:
    """Reference oracle for :func:`cluster_winners`: the seed
    implementation's Python loop over clusters (one full argsort each)."""
    win = jnp.zeros_like(eligible)
    for j in range(num_clusters):          # num_clusters is static & small
        in_j = clusters == j
        win_j = select_lowest_bids(bids, eligible & in_j, k_per_cluster,
                                   tie_break)
        win = win | win_j
    return win


# ----------------------------------------------------------------------
# reward models (eq 15-17)
# ----------------------------------------------------------------------

def reward_sample_share(won: jnp.ndarray, local_sizes: jnp.ndarray,
                        cfg: FLConfig) -> jnp.ndarray:
    """eq 15: winners split Rg/Nr proportionally to their sample counts.
    A zero-winner round (empty probe cluster + strict s_min) pays exactly
    zero — the any() guard keeps 0/0 out of the division."""
    per_round = cfg.total_reward / cfg.target_rounds
    w = won.astype(jnp.float32) * local_sizes.astype(jnp.float32)
    denom = jnp.maximum(w.sum(), 1e-9)
    return jnp.where(won.any(), per_round * w / denom, 0.0)


def reward_bid_share(won: jnp.ndarray, bids: jnp.ndarray,
                     cfg: FLConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """eq 16: each winner receives bid * Rg/Nr; the server keeps the rest.
    Returns (client_rewards, server_reward). A zero-winner round pays both
    sides exactly zero (no auction happened): without the guard the server
    share would degenerate to the whole per-round pool."""
    per_round = cfg.total_reward / cfg.target_rounds
    r = jnp.where(won, jnp.clip(bids, 0.0, 1.0) * per_round, 0.0)
    nwin = jnp.maximum(won.sum(), 1)
    server = jnp.where(won.any(), per_round - r.sum() / nwin, 0.0)
    return r, server
