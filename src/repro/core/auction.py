"""Auction mechanism (paper §IV): cost function, Nash-equilibrium bids,
winner selection and reward models. Fully vectorized over clients.

Roles: the aggregation server is the *auctioneer*; edge clients are
*bidders* selling data + compute service. Within each cluster the K_j
lowest bids win (reverse auction).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core import energy as E

INF = jnp.float32(1e9)


# ----------------------------------------------------------------------
# cost function (eq 12-14)
# ----------------------------------------------------------------------

def resource_cost(residual: jnp.ndarray, e_cp: jnp.ndarray,
                  cfg: FLConfig) -> jnp.ndarray:
    """Cr_{i,t} = phi^(E_res - E_cp) if the client can afford the round,
    else +inf (eq 12). Rises toward 1 as the battery approaches depletion.

    The exponent is taken on the battery *fraction* (E in [0,1]): with the
    percent scale the paper's Table-I phi=0.5 would give phi^100 ~ 8e-31 and
    the resource cost would be identically zero for every healthy client —
    degenerate. On the fraction scale Cr spans [phi, 1), monotone in drain,
    exactly the behaviour eq 12 describes. (Recorded in DESIGN.md.)
    """
    margin = (residual - e_cp) / 100.0
    cr = jnp.power(cfg.phi, margin)
    return jnp.where(margin > 0, cr, INF)


def service_cost(local_sizes: jnp.ndarray, history: jnp.ndarray,
                 cfg: FLConfig) -> jnp.ndarray:
    """Cs_{i,t} = chi * vartheta^Ns + zeta * (log_a(co + a) - 1)  (eq 13*).

    (*) Sign note, recorded in DESIGN.md §Paper-deviations: eq 13 as printed
    is ``zeta * (1 - log_a(co + a))``, which *decreases* the cost of
    frequently-selected clients — the opposite of the paper's stated intent
    ("with the increase of clients' participation rounds, our model
    appropriately reduces service quality") and of its Fig 9/10 results
    (energy balance improves vs random). Empirically the verbatim sign makes
    the auction *worse*-balanced than random selection (rich-get-richer);
    with the intended sign the Fig 9/10 behaviour reproduces. We default to
    the intended sign; ``cfg.history_verbatim=True`` restores the printed
    formula.
    """
    sample_term = jnp.power(cfg.vartheta, local_sizes.astype(jnp.float32))
    hist = jnp.log(history.astype(jnp.float32) + cfg.log_a) \
        / jnp.log(cfg.log_a)
    sign = -1.0 if cfg.history_verbatim else 1.0
    return cfg.chi * sample_term + cfg.zeta * sign * (hist - 1.0)


def cost(residual, local_sizes, history, cfg: FLConfig) -> jnp.ndarray:
    """c_{i,t} = alpha*Cs + gamma*Cr (eq 14), clipped into the bid domain
    [0,1] (the Nash analysis assumes bids on [0,1]); +inf (can't afford)
    stays +inf."""
    e_cp = E.compute_cost_energy(local_sizes, cfg)
    cr = resource_cost(residual, e_cp, cfg)
    cs = service_cost(local_sizes, history, cfg)
    c = cfg.alpha * cs + cfg.gamma * cr
    return jnp.where(cr >= INF, INF, jnp.clip(c, 0.0, 1.0))


# ----------------------------------------------------------------------
# optimal bid (Theorem 2)
# ----------------------------------------------------------------------

def optimal_bid(c: jnp.ndarray, n_j, k_j) -> jnp.ndarray:
    """b* = 1/(N_j-K_j+1) + (N_j-K_j)/(N_j-K_j+1) * c  — the symmetric
    Nash-equilibrium bid of Theorem 2. n_j/k_j may be scalars or per-client
    arrays (cluster-dependent)."""
    n_j = jnp.asarray(n_j, jnp.float32)
    k_j = jnp.asarray(k_j, jnp.float32)
    d = jnp.maximum(n_j - k_j, 0.0)
    bid = 1.0 / (d + 1.0) + d / (d + 1.0) * c
    return jnp.where(c >= INF, INF, bid)


def revenue(bid: jnp.ndarray, c: jnp.ndarray,
            won: jnp.ndarray) -> jnp.ndarray:
    """U_i = b - c if the client wins else 0 (eq 18)."""
    return jnp.where(won, bid - c, 0.0)


# ----------------------------------------------------------------------
# winner selection
# ----------------------------------------------------------------------

def select_lowest_bids(bids: jnp.ndarray, eligible: jnp.ndarray, k: int,
                       tie_break: jnp.ndarray | None = None
                       ) -> jnp.ndarray:
    """Boolean winner mask: k lowest eligible bids. Ties broken by the paper's
    rule (service cost then resource cost) via a composite key."""
    key = jnp.where(eligible, bids, INF)
    if tie_break is not None:
        key = key + 1e-6 * jnp.clip(tie_break, 0.0, 1.0)
    order = jnp.argsort(key)
    ranks = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    win = (ranks < k) & eligible & (key < INF)
    return win


def cluster_winners(bids: jnp.ndarray, clusters: jnp.ndarray,
                    eligible: jnp.ndarray, k_per_cluster: int,
                    num_clusters: int,
                    tie_break: jnp.ndarray | None = None) -> jnp.ndarray:
    """Winner mask over all clients: K_j lowest eligible bids per cluster."""
    win = jnp.zeros_like(eligible)
    for j in range(num_clusters):          # num_clusters is static & small
        in_j = clusters == j
        win_j = select_lowest_bids(bids, eligible & in_j, k_per_cluster,
                                   tie_break)
        win = win | win_j
    return win


# ----------------------------------------------------------------------
# reward models (eq 15-17)
# ----------------------------------------------------------------------

def reward_sample_share(won: jnp.ndarray, local_sizes: jnp.ndarray,
                        cfg: FLConfig) -> jnp.ndarray:
    """eq 15: winners split Rg/Nr proportionally to their sample counts."""
    per_round = cfg.total_reward / cfg.target_rounds
    w = won.astype(jnp.float32) * local_sizes.astype(jnp.float32)
    denom = jnp.maximum(w.sum(), 1e-9)
    return per_round * w / denom


def reward_bid_share(won: jnp.ndarray, bids: jnp.ndarray,
                     cfg: FLConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """eq 16: each winner receives bid * Rg/Nr; the server keeps the rest.
    Returns (client_rewards, server_reward)."""
    per_round = cfg.total_reward / cfg.target_rounds
    r = jnp.where(won, jnp.clip(bids, 0.0, 1.0) * per_round, 0.0)
    nwin = jnp.maximum(won.sum(), 1)
    server = per_round - r.sum() / nwin
    return r, server
