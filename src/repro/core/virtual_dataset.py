"""Federated virtual dataset (paper §III-B).

The virtual dataset of round t is xi_t = union of the selected clients'
local datasets: distributed SGD over the selected cohort is equivalent to
centralized (mini-batch) SGD over xi_t (eq 4-8). The selection scheme's job
is to make the *distribution* of xi_t match the global distribution in every
round; these helpers measure exactly that (used by tests and benchmarks to
reproduce the paper's Fig 3/4 reasoning).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


def label_histogram(labels: jnp.ndarray, num_classes: int) -> jnp.ndarray:
    h = jnp.zeros((num_classes,)).at[labels].add(1.0)
    return h / jnp.maximum(h.sum(), 1.0)


def virtual_dataset_histogram(client_labels: Sequence[np.ndarray],
                              selected: np.ndarray,
                              num_classes: int) -> jnp.ndarray:
    """Label distribution of xi_t = U_{k in selected} xi_k."""
    parts = [client_labels[i] for i in np.nonzero(selected)[0]]
    if not parts:
        return jnp.full((num_classes,), 1.0 / num_classes)
    return label_histogram(jnp.concatenate([jnp.asarray(p) for p in parts]),
                           num_classes)


def tv_distance(p: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Total-variation distance between label distributions — the
    heterogeneity of xi_t w.r.t. the global distribution."""
    return 0.5 * jnp.abs(p - q).sum()


def virtual_dataset_gap(client_labels, selected, global_hist,
                        num_classes: int) -> float:
    """TV(xi_t distribution, global distribution) — smaller means the round's
    virtual dataset better matches the global data (the paper's goal)."""
    h = virtual_dataset_histogram(client_labels, selected, num_classes)
    return float(tv_distance(h, jnp.asarray(global_hist)))


def virtual_dataset_size(client_sizes: np.ndarray,
                         selected: np.ndarray) -> int:
    return int((client_sizes * selected).sum())
