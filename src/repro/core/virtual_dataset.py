"""Federated virtual dataset (paper §III-B).

The virtual dataset of round t is xi_t = union of the selected clients'
local datasets: distributed SGD over the selected cohort is equivalent to
centralized (mini-batch) SGD over xi_t (eq 4-8). The selection scheme's job
is to make the *distribution* of xi_t match the global distribution in every
round; these helpers measure exactly that (used by tests and benchmarks to
reproduce the paper's Fig 3/4 reasoning).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


def label_histogram(labels: jnp.ndarray, num_classes: int) -> jnp.ndarray:
    h = jnp.zeros((num_classes,)).at[labels].add(1.0)
    return h / jnp.maximum(h.sum(), 1.0)


def virtual_dataset_histogram(client_labels: Sequence[np.ndarray],
                              selected: np.ndarray,
                              num_classes: int) -> jnp.ndarray:
    """Label distribution of xi_t = U_{k in selected} xi_k."""
    parts = [client_labels[i] for i in np.nonzero(selected)[0]]
    if not parts:
        return jnp.full((num_classes,), 1.0 / num_classes)
    return label_histogram(jnp.concatenate([jnp.asarray(p) for p in parts]),
                           num_classes)


def tv_distance(p: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Total-variation distance between label distributions — the
    heterogeneity of xi_t w.r.t. the global distribution."""
    return 0.5 * jnp.abs(p - q).sum()


def virtual_dataset_gap(client_labels, selected, global_hist,
                        num_classes: int) -> float:
    """TV(xi_t distribution, global distribution) — smaller means the round's
    virtual dataset better matches the global data (the paper's goal)."""
    h = virtual_dataset_histogram(client_labels, selected, num_classes)
    return float(tv_distance(h, jnp.asarray(global_hist)))


def virtual_dataset_size(client_sizes: np.ndarray,
                         selected: np.ndarray) -> int:
    return int((client_sizes * selected).sum())


# ----------------------------------------------------------------------
# device-side round metrics (repro.core.rounds)
# ----------------------------------------------------------------------

def client_count_histograms(client_labels: Sequence[np.ndarray],
                            num_classes: int) -> np.ndarray:
    """(N, num_classes) per-client label *counts* (not normalized) —
    precomputed once on host so the per-round vds-gap reduces to one
    masked matvec on device."""
    h = np.zeros((len(client_labels), num_classes), np.float32)
    for i, lab in enumerate(client_labels):
        np.add.at(h[i], np.asarray(lab), 1.0)
    return h


def virtual_dataset_gap_device(selected: jnp.ndarray,
                               count_hists: jnp.ndarray,
                               global_hist: jnp.ndarray) -> jnp.ndarray:
    """Jit-friendly twin of :func:`virtual_dataset_gap`: xi_t's label
    histogram is the winner-masked sum of precomputed per-client counts
    (one (N,) @ (N, C) matvec — counts are integer-valued floats, so the
    sum matches the concatenate-then-histogram host path exactly). Empty
    rounds fall back to the uniform histogram, as the host path does."""
    h = selected.astype(jnp.float32) @ count_hists          # (C,)
    num_classes = count_hists.shape[1]
    hist = jnp.where(selected.any(), h / jnp.maximum(h.sum(), 1.0),
                     1.0 / num_classes)
    return tv_distance(hist, global_hist)
