"""Energy model of the MEC federated system (paper §IV-A).

Every client has a battery expressed in *percent* (0..100). Per training
round a selected client pays

    E_sum = E_cp + E_cm                                   (eq 9)
    E_cm  = E_re + E_se                                   (eq 10)
    E_cp  = Ns_i * rho / 100                              (eq 11)

with rho = "energy per 100 samples" (Table I: 0.2). The paper's headline
system metric is the **energy balance**: the standard deviation of residual
energy across all clients (smaller = better balanced).

All state is struct-of-arrays (jnp) so selection math vectorizes on device.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig


def init_energy(cfg: FLConfig, key) -> jnp.ndarray:
    """Initial residual energy per client, percent scale [0, 100].

    case1 ('full'): everyone at 100%.
    case2 ('normal'): N(mean, std) truncated to [low, high] (paper §V-A).
    """
    n = cfg.num_clients
    if cfg.init_energy_mode == "full":
        return jnp.full((n,), 100.0)
    e = cfg.init_energy_mean * 100.0 + cfg.init_energy_std * 100.0 \
        * jax.random.truncated_normal(
            key, (cfg.init_energy_low - cfg.init_energy_mean)
            / cfg.init_energy_std,
            (cfg.init_energy_high - cfg.init_energy_mean)
            / cfg.init_energy_std, (n,))
    return jnp.clip(e, cfg.init_energy_low * 100.0,
                    cfg.init_energy_high * 100.0)


def compute_cost_energy(local_sizes: jnp.ndarray, cfg: FLConfig) -> jnp.ndarray:
    """E_cp per client for one local round (eq 11)."""
    return local_sizes.astype(jnp.float32) * cfg.energy_per_100_samples / 100.0


def round_energy(local_sizes: jnp.ndarray, cfg: FLConfig) -> jnp.ndarray:
    """E_sum per client if selected this round (eq 9-11)."""
    return (compute_cost_energy(local_sizes, cfg)
            + cfg.energy_rx + cfg.energy_tx) * cfg.local_epochs


def apply_round(residual: jnp.ndarray, selected: jnp.ndarray,
                local_sizes: jnp.ndarray, cfg: FLConfig) -> jnp.ndarray:
    """Subtract this round's consumption from selected clients (floored at 0).

    The energy term is pinned behind an optimization barrier and applied
    via a select rather than ``residual - spend * selected``: inside fused
    programs (lax.scan) XLA contracts the trailing multiply of
    round_energy with this subtraction into an FMA, which it does not do
    eagerly — scanned and eager energy trajectories would differ by 1 ulp.
    The barrier forces the multiply to round first, keeping both paths
    bit-identical (tests/test_rounds.py equivalence)."""
    e = jax.lax.optimization_barrier(round_energy(local_sizes, cfg))
    return jnp.where(selected, jnp.maximum(residual - e, 0.0), residual)


def energy_balance(residual: jnp.ndarray) -> jnp.ndarray:
    """The paper's balance metric: std-dev of residual energy (Fig 9/10)."""
    return jnp.std(residual)


def energy_stats(residual: jnp.ndarray) -> dict:
    """On-device fleet energy summary for the fused round control plane
    (repro.core.rounds): std (the Fig 9/10 balance metric), mean, min —
    computed inside the round program so logging costs no extra host sync."""
    return {
        "energy_std": jnp.std(residual),
        "energy_mean": jnp.mean(residual),
        "energy_min": jnp.min(residual),
    }
