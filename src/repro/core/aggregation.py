"""Screened robust FedAvg: the defended aggregation layer.

The cohort runtimes' fast paths fuse the FedAvg reduction into their
compiled training programs, which is exactly right until a Byzantine
client returns a poisoned update — a fused ``sum_k p_k w_k`` happily
propagates one NaN row into the global model.  When
``cfg.defended`` (any ``--defense`` or an active ``--attack``) the
server routes stage-3 through this module instead: every runtime
returns the cohort's *per-client flat param deltas* as one ``(C, D)``
matrix (:class:`UpdateBatch`) and ONE fused jitted program —
:func:`make_screened_step` — applies the corruption model
(repro.sim.dynamics.corrupt_updates, the attack happens "on device,
after local training"), screens, aggregates and updates the auction
reputation, all on device:

  1. **quarantine** — rows with any non-finite coordinate are excluded
     from the weighted sum and the surviving rows' weights are
     renormalized (never silently zeroed: a quarantined update
     contributes *nothing*, it does not drag the aggregate toward 0).
     Quarantine precedes every other screen because a NaN row poisons
     any statistic computed over it (norms, medians, sorts).
  2. **defense** (``cfg.defense``):
     ``clip``    — each surviving row's l2 norm is clipped to
                   ``clip_mult x`` a running median norm (EMA with rate
                   ``clip_beta`` over per-round cohort medians), then
                   the renormalized weighted mean;
     ``trimmed`` — coordinate-wise trimmed mean: ``ceil(trim_frac * V)``
                   values trimmed from EACH tail per coordinate
                   (unweighted over the kept band, the standard
                   estimator);
     ``median``  — coordinate-wise median of the surviving rows;
     ``none``    — the plain weighted sum (corrupted rows included:
                   this is the attack-baseline the benchmark degrades).
  3. **reputation** — one on-device scatter adds a strike per
     quarantined client into ``SelectionState.strikes``; the fused
     round step bans clients at ``strike_threshold`` and decays strikes
     per round (repro.core.selection) — no new per-round host syncs,
     the winner mask stays the only unconditional fetch.

Bit-equality boundary: with ``cfg.defended`` False the server never
constructs any of this and the pre-defense traces are unchanged.  The
screened program itself is compiled ONCE per run: the row axis is
padded to the static :func:`screen_capacity` bound, so shifting cohort
sizes never retrace (asserted in tests/test_robust.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import FLConfig

DEFENSES = ("none", "clip", "trimmed", "median")
DEFENSE_MODES = ("static", "adaptive")


@dataclass
class DefenseState:
    """Device-resident carried state of the screened aggregation — the
    auto-tuning statistics that replace PR-8's single clip-EMA scalar.
    A pytree whose trailing fields follow the Optional-last-field rule
    (SelectionState.staleness/strikes): a feature that is off keeps its
    field ``None`` (an empty pytree node), so static-mode and
    watchdog-off traces carry exactly the one clip-EMA leaf they always
    did.

      * ``clip_ema``  — running median survivor norm (0 = unseeded);
        the clip defense's threshold scale, same EMA as PR 8.
      * ``mad_ema``   — running median absolute deviation of survivor
        norms (adaptive mode only): the width of the honest norm band.
      * ``pressure``  — EMA of the per-round screen rate (quarantined +
        outlier fraction, adaptive mode only): rises under attack,
        decays as the fleet heals — the auto-tuning signal that
        tightens ``adapt_k`` and relaxes it back.
      * ``tighten``   — cumulative watchdog tightening factor (>= 1,
        watchdog on only): every rollback multiplies it by
        ``cfg.watchdog_tighten`` and the screen thresholds divide by it.
    """

    clip_ema: jnp.ndarray
    mad_ema: Optional[jnp.ndarray] = None
    pressure: Optional[jnp.ndarray] = None
    tighten: Optional[jnp.ndarray] = None


jax.tree_util.register_dataclass(
    DefenseState,
    data_fields=["clip_ema", "mad_ema", "pressure", "tighten"],
    meta_fields=[])


def init_defense_state(cfg: FLConfig) -> DefenseState:
    """Round-0 defense state under ``cfg``: adaptive statistics exist
    only in adaptive mode, the tighten factor only with the watchdog on
    (None fields are empty pytree nodes — the bit-identity mechanism)."""
    if cfg.defense_mode not in DEFENSE_MODES:
        raise ValueError(f"unknown defense_mode={cfg.defense_mode!r}; "
                         f"expected {DEFENSE_MODES}")
    adaptive = cfg.defense_mode == "adaptive"
    return DefenseState(
        clip_ema=jnp.float32(0.0),
        mad_ema=jnp.float32(0.0) if adaptive else None,
        pressure=jnp.float32(0.0) if adaptive else None,
        tighten=jnp.float32(1.0) if cfg.watchdog_enabled else None)


@dataclass
class UpdateBatch:
    """A cohort's per-client updates, as the runtimes hand them to the
    screened aggregation: ``deltas`` is the (C, D) float32 matrix of
    flat param deltas vs the dispatched globals (row order = packer
    order, padding rows all-zero), ``weights`` the matching (C,) global
    FedAvg weights (sum to 1 over real rows, 0 on padding), and
    ``client_idx`` the (C,) global client ids (-1 on padding)."""

    deltas: jnp.ndarray
    weights: np.ndarray
    client_idx: np.ndarray


def flat_size(params) -> int:
    """Total flat parameter count D (leaf order = jax.tree.leaves)."""
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(params)))


def screen_capacity(cfg: FLConfig) -> int:
    """Static row-capacity bound of the screened program: the largest
    cohort any selection scheme can produce (per-cluster k x J or the
    random scheme's K), rounded up to a power of two.  One compile per
    run — shifting cohort sizes pad up to this and never retrace."""
    from repro.core.selection import k_per_cluster
    k_total = max(int(round(cfg.select_ratio * cfg.num_clients)), 1)
    bound = min(cfg.num_clients,
                max(k_total, k_per_cluster(cfg) * cfg.num_clusters))
    cap = 1
    while cap < bound:
        cap *= 2
    return cap


def make_flat_delta(params_like):
    """Jitted ``(new_params, old_params) -> (D,) float32`` flat delta —
    the sequential runtime's per-client flattening; leaf order matches
    every other runtime's (jax.tree.leaves)."""
    def flat(new, old):
        d = jax.tree.map(lambda a, b: (a - b).astype(jnp.float32), new, old)
        return jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(d)])

    return jax.jit(flat)


def make_apply_delta(params_like):
    """Jitted ``(params, (D,) flat delta) -> params``: split, reshape
    and add — the inverse of the runtimes' flattening."""
    leaves = jax.tree.leaves(params_like)
    shapes = [l.shape for l in leaves]
    sizes = [int(np.prod(s)) for s in shapes]
    offsets = np.cumsum([0] + sizes)
    treedef = jax.tree.structure(params_like)

    def apply(params, flat):
        plv = jax.tree.leaves(params)
        new = [p + jax.lax.dynamic_slice_in_dim(flat, int(o), n)
               .reshape(s).astype(p.dtype)
               for p, o, n, s in zip(plv, offsets[:-1], sizes, shapes)]
        return jax.tree.unflatten(treedef, new)

    return jax.jit(apply)


def _percentile_sorted(sorted_vals: jnp.ndarray, v: jnp.ndarray,
                       q: float) -> jnp.ndarray:
    """q-th percentile of the first ``v`` entries of an ascending-sorted
    vector (invalid entries sorted to +inf at the tail); 0 when v = 0."""
    cap = sorted_vals.shape[0]
    idx = jnp.clip((q * (v - 1).astype(jnp.float32)).astype(jnp.int32),
                   0, cap - 1)
    return jnp.where(v > 0, jnp.take(sorted_vals, idx), 0.0)


def make_screened_step(cfg: FLConfig):
    """Compile the fused corrupt -> quarantine -> (adaptive band screen)
    -> defend -> aggregate -> reputation program.  Signature::

        (deltas (cap, D) f32, weights (cap,) f32, valid (cap,) bool,
         adv (cap,) bool, ids (cap,) int32, strikes (N,) f32,
         dstate: DefenseState, round_idx () i32, key)
          -> (agg_delta (D,), new_strikes (N,), new_dstate,
              report: dict of device scalars)

    ``dstate`` carries the running defense statistics (clip EMA and, in
    adaptive mode, the MAD band + pressure EMA — see
    :class:`DefenseState`); ``round_idx`` feeds phase-aware attacks
    (on_off).  The report rides the server's pending buffer and drains
    with the one batched logging fetch.  ``cfg`` is closed over
    (static): one compile per run regardless of mode."""
    # deferred: repro.sim.runtime (imported by the repro.sim package
    # init) needs UpdateBatch from this module, so a top-level dynamics
    # import here would be circular
    from repro.sim import dynamics as DYN
    defense = cfg.defense
    if defense not in DEFENSES:
        raise ValueError(f"unknown defense={defense!r}; expected {DEFENSES}")
    adaptive = cfg.defense_mode == "adaptive" and defense != "none"
    if cfg.defense_mode not in DEFENSE_MODES:
        raise ValueError(f"unknown defense_mode={cfg.defense_mode!r}; "
                         f"expected {DEFENSE_MODES}")

    def screen(deltas, weights, valid, adv, ids, strikes, dstate,
               round_idx, key):
        obs.jax_stats.note_trace("screened_agg")   # trace-time only
        cap = deltas.shape[0]
        clip_state = dstate.clip_ema
        # adaptive adversaries observe the defense's carried state: the
        # clip EMA and round phase flow into the corruption model inside
        # the same fused program — threat awareness costs no host sync
        deltas = DYN.corrupt_updates(cfg, key, deltas, adv, valid,
                                     clip_ema=clip_state,
                                     round_idx=round_idx)
        finite = jnp.isfinite(deltas).all(axis=1)
        if defense == "none":
            # no screening: corrupted rows flow into the aggregate (the
            # attack baseline) — quarantine must not silently save it
            quarantined = jnp.zeros_like(valid)
            ok = valid
        else:
            quarantined = valid & ~finite
            ok = valid & finite
        # metrics are computed over finite valid rows only, so a NaN row
        # never poisons the norm statistics even with the defense off
        mok = valid & finite
        safe = jnp.where(mok[:, None], deltas, 0.0)
        norms = jnp.sqrt(jnp.square(safe).sum(axis=1))
        v_metric = mok.sum()
        sorted_norms = jnp.sort(jnp.where(mok, norms, jnp.inf))
        p50 = _percentile_sorted(sorted_norms, v_metric, 0.50)
        p99 = _percentile_sorted(sorted_norms, v_metric, 0.99)
        # running median norm (EMA over round medians; seeds on first
        # non-empty round) — the clip defense's threshold scale
        new_clip = jnp.where(
            v_metric > 0,
            jnp.where(clip_state > 0,
                      (1.0 - cfg.clip_beta) * clip_state
                      + cfg.clip_beta * p50,
                      p50),
            clip_state)
        # watchdog tightening: a rollback multiplies the cumulative
        # factor, every threshold divides by it (None = watchdog off,
        # trace unchanged)
        tight = dstate.tighten if dstate.tighten is not None else None
        if adaptive:
            # auto-tuned outlier band: norms above the running
            # median + k_eff x MAD are screened out (excluded like
            # quarantine) and earn fractional strikes.  k_eff tightens
            # as the pressure EMA rises and relaxes as it falls — this
            # is what catches a sub_clip attacker sitting under the
            # STATIC threshold: its norm still lands far outside the
            # honest MAD band.
            dev = jnp.where(mok, jnp.abs(norms - p50), jnp.inf)
            mad = _percentile_sorted(jnp.sort(dev), v_metric, 0.50)
            new_mad = jnp.where(
                v_metric > 0,
                jnp.where(dstate.clip_ema > 0,
                          (1.0 - cfg.clip_beta) * dstate.mad_ema
                          + cfg.clip_beta * mad,
                          mad),
                dstate.mad_ema)
            k_eff = cfg.adapt_k / (1.0 + cfg.adapt_gain * dstate.pressure)
            if tight is not None:
                k_eff = k_eff / tight
            mad_safe = jnp.maximum(new_mad, cfg.adapt_mad_floor * new_clip)
            thr_band = new_clip + k_eff * mad_safe
            outlier = mok & (norms > thr_band) & (new_clip > 0)
            ok = ok & ~outlier
        else:
            new_mad = dstate.mad_ema
            outlier = jnp.zeros_like(valid)
        okf = ok.astype(jnp.float32)
        thr = cfg.clip_mult * new_clip
        if tight is not None:
            thr = thr / tight
        clipped = mok & (norms > thr)
        v = ok.sum()

        if defense == "none":
            agg = (weights * okf) @ deltas
        elif defense == "clip":
            factor = jnp.where(clipped, thr / jnp.maximum(norms, 1e-12),
                               1.0)
            w_ok = weights * okf
            mass = w_ok.sum()
            agg = jnp.where(mass > 0,
                            (w_ok / jnp.maximum(mass, 1e-12))
                            @ (safe * factor[:, None]),
                            jnp.zeros((deltas.shape[1],), jnp.float32))
        elif defense == "trimmed":
            vals = jnp.where(ok[:, None], deltas, jnp.inf)
            s = jnp.sort(vals, axis=0)
            k = jnp.ceil(cfg.trim_frac * v.astype(jnp.float32)
                         ).astype(jnp.int32)
            k = jnp.clip(k, 0, jnp.maximum((v - 1) // 2, 0))
            ranks = jnp.arange(cap)[:, None]
            keep = (ranks >= k) & (ranks < v - k)
            kept = jnp.where(keep, s, 0.0)
            agg = jnp.where(v > 0,
                            kept.sum(axis=0)
                            / jnp.maximum(v - 2 * k, 1).astype(jnp.float32),
                            jnp.zeros((deltas.shape[1],), jnp.float32))
        else:   # median
            vals = jnp.where(ok[:, None], deltas, jnp.inf)
            s = jnp.sort(vals, axis=0)
            lo = jnp.clip((v - 1) // 2, 0, cap - 1)
            hi = jnp.clip(v // 2, 0, cap - 1)
            agg = jnp.where(v > 0,
                            0.5 * (jnp.take(s, lo, axis=0)
                                   + jnp.take(s, hi, axis=0)),
                            jnp.zeros((deltas.shape[1],), jnp.float32))

        # reputation feedback: one on-device scatter per screen — strikes
        # reach the host only through metrics drained at logging
        # boundaries (num_banned), never a dedicated per-round sync.
        # Band outliers earn a fractional strike (0.0 add when the band
        # screen is off keeps static-mode strike values bit-exact).
        n = strikes.shape[0]
        new_strikes = strikes.at[jnp.clip(ids, 0, n - 1)].add(
            jnp.where(quarantined, 1.0, 0.0)
            + cfg.outlier_strike * jnp.where(outlier, 1.0, 0.0))
        if adaptive:
            # attack-pressure EMA: fraction of finite rows rejected this
            # round (quarantine + band); feeds next round's k_eff
            rejected = (quarantined | outlier).sum().astype(jnp.float32)
            frac = rejected / jnp.maximum(v_metric, 1).astype(jnp.float32)
            new_pressure = ((1.0 - cfg.pressure_beta) * dstate.pressure
                            + cfg.pressure_beta * frac)
        else:
            new_pressure = dstate.pressure
        new_dstate = DefenseState(clip_ema=new_clip, mad_ema=new_mad,
                                  pressure=new_pressure,
                                  tighten=dstate.tighten)
        report: Dict[str, jnp.ndarray] = {
            "num_quarantined": quarantined.sum(),
            "num_screened": outlier.sum(),
            "num_survivors": v,
            "survivor_frac": jnp.where(
                valid.sum() > 0,
                v.astype(jnp.float32)
                / jnp.maximum(valid.sum(), 1).astype(jnp.float32),
                0.0),
            "clipped_frac": jnp.where(
                v_metric > 0,
                clipped.sum() / jnp.maximum(v_metric, 1).astype(jnp.float32),
                0.0),
            "update_norm_p50": p50,
            "update_norm_p99": p99,
            "defense_pressure": (new_pressure if adaptive
                                 else jnp.float32(0.0)),
        }
        return agg, new_strikes, new_dstate, report

    return jax.jit(screen)
