"""Model adapters: the FL server is model-agnostic; an adapter binds a
trainable model (the paper's CNNs, or any registry transformer) to the
(loss, grad, metrics) interface the federated loop needs.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.models import cnn as CNN
from repro.models import model as MD


@dataclass(frozen=True)
class ModelAdapter:
    init: Callable[[Any], Any]                  # key -> params
    loss: Callable[[Any, Dict], jnp.ndarray]    # (params, batch) -> scalar
    grad: Callable[[Any, Dict], Any]            # (params, batch) -> grads
    accuracy: Callable[[Any, Dict], jnp.ndarray]
    batch_fields: tuple = ("x", "y")


def cnn_adapter(variant: str) -> ModelAdapter:
    loss = partial(CNN.cnn_loss, variant=variant)
    return ModelAdapter(
        init=lambda key: CNN.init_cnn(key, variant),
        loss=jax.jit(loss),
        grad=jax.jit(jax.grad(loss)),
        accuracy=jax.jit(partial(CNN.cnn_accuracy, variant=variant)),
    )


def transformer_adapter(cfg) -> ModelAdapter:
    """FL over a registry architecture: batches carry token sequences; the
    'label' used for non-IID partitioning is the topic id (data pipeline).

    Batch format: {"x": tokens (B, S), "y": topic (unused by loss)}. The LM
    objective is next-token prediction over x.
    """

    def loss(params, batch):
        toks = batch["x"]
        lm_batch = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "mask": jnp.ones_like(toks[:, 1:], jnp.float32),
        }
        return MD.loss_fn(cfg, params, lm_batch)

    def accuracy(params, batch):
        toks = batch["x"]
        logits = MD.logits_fn(cfg, params, toks[:, :-1])
        return (logits.argmax(-1) == toks[:, 1:]).mean()

    return ModelAdapter(
        init=lambda key: MD.init_params(cfg, key),
        loss=jax.jit(loss),
        grad=jax.jit(jax.grad(loss)),
        accuracy=jax.jit(accuracy),
    )
