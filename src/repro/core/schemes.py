"""Selection-scheme registry: pluggable per-round winner-pick programs
for the fused round control plane (repro.core.rounds).

The paper's cluster-then-auction selection was hardcoded into
``rounds._round_body``; this registry makes the control plane a scheme
x distribution benchmark matrix instead.  Every scheme is a
:class:`SelectionScheme` — three jittable hooks plus an optional carried
state — and every registered scheme compiles into the SAME round
programs: the live jitted step (``rounds.make_round_step``), the
``lax.scan``-over-rounds fast path (``rounds.simulate_rounds``, N=1M
clients x thousands of rounds) and the seed per-round reference, with
zero warm retraces (counter-asserted in tests/test_schemes.py).

Interface contract (DESIGN.md §Scheme registry):

  * ``init_state(cfg) -> Optional[pytree]`` — the scheme's carried
    state, threaded as ``SelectionState.scheme_state`` across rounds
    (through jit, scan and checkpoints).  ``None`` for stateless
    schemes: a None field is an empty pytree node, so stateless schemes
    trace the exact pre-registry round programs (the Optional-last-field
    pattern proven by ``staleness`` and ``strikes``).
  * ``select(state, cfg, key, winners_impl, avail) -> (win, info)`` —
    the eligibility/bid transform + winner pick.  ``avail`` is the
    conjunction of fleet-dynamics availability and auction-reputation
    trust (strikes below threshold), composed UPSTREAM in
    ``rounds._round_body`` — schemes must treat it as a hard eligibility
    mask.  ``info`` must contain ``bids`` (the reward models read it).
  * ``update_state(state, new_state, cfg, win, info, client_rewards)
    -> (new_scheme_state, metrics)`` — advance the carried state after
    the energy/history update and emit per-scheme round scalars (device
    values; drained with the round's one batched fetch).

Built-in zoo:

  * ``paper``            — the oracle: selection.select_round verbatim
    (itself dispatching on ``cfg.scheme``, the paper's own baselines).
  * ``random``           — uniform K_j per-cluster picks among available
    clients (the paper's baseline, made availability/reputation-aware).
  * ``fedcs``            — FedCS deadline-constrained selection (Nishio
    & Yonetani, arXiv:1804.08333): the paper's pricing, but bid-time
    eligibility additionally requires the sim.dynamics latency model's
    PREDICTED round latency to meet the deadline — the auction finally
    sees deadline risk instead of discovering it post-hoc.
  * ``longterm_auction`` — long-term budget-feasible auction
    (arXiv:2508.09181): a Lyapunov virtual queue tracks cumulative
    overspend vs the per-round budget Rg/Nr; the backlog adaptively caps
    admissible bids so the time-average payout meets the budget, and the
    whole ledger (spent, queue, per-client payments) rides
    ``scheme_state``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import auction as A
from repro.core import selection as SEL

Metrics = Dict[str, jnp.ndarray]
SelectFn = Callable[..., Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]]


@dataclass(frozen=True)
class SelectionScheme:
    """One pluggable selection scheme (see module docstring for the
    hook contract).  Frozen: schemes are registered once at import and
    shared across configs — all per-run knobs come from ``cfg``."""

    name: str
    select: SelectFn
    init_state: Callable[[FLConfig], Optional[Any]]
    update_state: Callable[..., Tuple[Optional[Any], Metrics]]
    # True when init_state returns a non-None pytree: the obs schema
    # validator requires such schemes to log budget scalars every round
    stateful: bool = False


_REGISTRY: Dict[str, SelectionScheme] = {}


def register(scheme: SelectionScheme) -> SelectionScheme:
    if scheme.name in _REGISTRY:
        raise ValueError(f"scheme {scheme.name!r} already registered")
    _REGISTRY[scheme.name] = scheme
    return scheme


def get_scheme(name: str) -> SelectionScheme:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown selection scheme {name!r}; registered schemes: "
            f"{scheme_names()}") from None


def scheme_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def stateful_scheme_names() -> Tuple[str, ...]:
    """Schemes that thread a scheme_state pytree (the obs schema
    validator's STATEFUL_SCHEMES must mirror this — cross-checked by
    tests/test_schemes.py so the two can't drift)."""
    return tuple(sorted(n for n, s in _REGISTRY.items() if s.stateful))


def init_scheme_state(cfg: FLConfig) -> Optional[Any]:
    """The scheme_state for a fresh fleet under ``cfg.scheme_select``."""
    return get_scheme(cfg.scheme_select).init_state(cfg)


# ----------------------------------------------------------------------
# stateless no-op hooks
# ----------------------------------------------------------------------

def _no_state(cfg: FLConfig) -> None:
    return None


def _keep_state(state, new_state, cfg, win, info, client_rewards
                ) -> Tuple[Optional[Any], Metrics]:
    return state.scheme_state, {}


# ----------------------------------------------------------------------
# paper — the oracle (selection.select_round verbatim)
# ----------------------------------------------------------------------

register(SelectionScheme(
    name="paper",
    select=SEL.select_round,
    init_state=_no_state,
    update_state=_keep_state,
))


# ----------------------------------------------------------------------
# random — uniform per-cluster picks, availability/reputation-aware
# ----------------------------------------------------------------------

def random_select(state: SEL.SelectionState, cfg: FLConfig, key,
                  winners_impl: str = "segmented",
                  avail: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Uniform K_j picks per cluster among ELIGIBLE clients only.

    Same 4-way key split as select_round (keys[1] drives the pick — the
    chain discipline the reference-sampler regression pins down), and
    the pick is the segmented sampler selection._random_per_cluster,
    whose per-cluster argsort loop survives as the oracle.  Unlike the
    legacy ``cfg.scheme == "random"`` baseline (which models a server
    with no liveness signal and draws blind), ``avail`` here is a hard
    mask: the sampler's empty-cluster relaxation never re-admits an
    offline or reputation-banned client — the post-pick conjunction
    keeps a fully-gated cluster empty instead."""
    n = cfg.num_clients
    keys = jax.random.split(key, 4)
    eligible = (jnp.ones((n,), bool) if avail is None else avail)
    win = SEL._random_per_cluster(keys[1], state, cfg, eligible) & eligible
    return win, {"bids": jnp.zeros((n,))}


register(SelectionScheme(
    name="random",
    select=random_select,
    init_state=_no_state,
    update_state=_keep_state,
))


# ----------------------------------------------------------------------
# fedcs — deadline-feasibility gating on predicted latency at bid time
# ----------------------------------------------------------------------

# fold_in tag separating the bid-time latency-prediction draw from every
# other consumer of the round key (the fault model's ACTUAL latency draw
# comes from the dedicated dynamics chain, so prediction stays a model
# of the hazard, not an oracle over it)
_FEDCS_PRED_TAG = 0xFEDC5


def fedcs_deadline(cfg: FLConfig) -> float:
    """The deadline fedcs gates on: the fault model's ``cfg.deadline``
    when dynamics enforce one, else the scheme's own bound — so with
    dynamics on, the auction predicts the exact hazard the fleet runs
    under."""
    return cfg.deadline if cfg.deadline > 0.0 else cfg.fedcs_deadline


def fedcs_predicted_latency(state: SEL.SelectionState, cfg: FLConfig,
                            key) -> jnp.ndarray:
    """Bid-time per-client latency prediction: the sim.dynamics latency
    model (compute scales with local sample count x the straggler
    profile's energy-dependent slowdown) evaluated on the round-start
    state under a dedicated fold of the round key.  Deterministic given
    (key, state) — tests recompute it to assert feasibility."""
    from repro.sim import dynamics as DYN
    return DYN.round_latency(cfg, jax.random.fold_in(key, _FEDCS_PRED_TAG),
                             state.residual, state.local_sizes)


def fedcs_select(state: SEL.SelectionState, cfg: FLConfig, key,
                 winners_impl: str = "segmented",
                 avail: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """FedCS-style deadline-constrained selection: the paper's pricing
    (cost -> Nash bids -> s_min probe), but a client whose PREDICTED
    latency misses the deadline cannot enter the auction — closing the
    PR-7 follow-on where selection was blind to the deadline the fault
    model then enforced.  A cluster with no feasible member selects no
    one (never relaxed: an infeasible winner would just be LATE)."""
    kj = SEL.k_per_cluster(cfg)
    keys = jax.random.split(key, 4)
    c, bids = A.price_round(state.clusters, state.residual,
                            state.local_sizes, state.history, kj, cfg)
    smin = SEL._sample_threshold(keys[0], state, cfg, bids)
    pred_lat = fedcs_predicted_latency(state, cfg, key)
    feasible = pred_lat <= fedcs_deadline(cfg)
    eligible = (state.local_sizes >= smin) & (c < A.INF) & feasible
    if avail is not None:
        eligible = eligible & avail
    cs = A.service_cost(state.local_sizes, state.history, cfg)
    win = A.cluster_winners(A.effective_bids(bids, state.strikes, cfg),
                            state.clusters, eligible, kj,
                            cfg.num_clusters, tie_break=cs,
                            impl=winners_impl)
    return win, {"bids": bids, "costs": c, "s_min": smin,
                 "pred_latency": pred_lat,
                 "revenue": A.revenue(bids, c, win)}


def _fedcs_update(state, new_state, cfg, win, info, client_rewards
                  ) -> Tuple[Optional[Any], Metrics]:
    nwin = jnp.maximum(win.sum(), 1)
    return None, {
        "pred_latency_mean": jnp.where(win, info["pred_latency"],
                                       0.0).sum() / nwin,
        "num_feasible": (info["pred_latency"]
                         <= fedcs_deadline(cfg)).sum(),
    }


register(SelectionScheme(
    name="fedcs",
    select=fedcs_select,
    init_state=_no_state,
    update_state=_fedcs_update,
))


# ----------------------------------------------------------------------
# longterm_auction — budget/payment state carried across rounds
# ----------------------------------------------------------------------

@dataclass
class LongTermState:
    """The long-term auction's carried ledger (a pytree — flows through
    jit/scan/checkpoints as ``SelectionState.scheme_state``)."""

    spent: jnp.ndarray    # () f32 cumulative payout over the whole run
    queue: jnp.ndarray    # () f32 Lyapunov backlog vs the per-round budget
    paid: jnp.ndarray     # (N,) f32 cumulative per-client payments


jax.tree_util.register_dataclass(
    LongTermState, data_fields=["spent", "queue", "paid"], meta_fields=[])


def _longterm_init(cfg: FLConfig) -> LongTermState:
    return LongTermState(
        spent=jnp.float32(0.0), queue=jnp.float32(0.0),
        paid=jnp.zeros((cfg.num_clients,), jnp.float32))


def longterm_bid_cap(cfg: FLConfig, queue) -> jnp.ndarray:
    """Backlog-adaptive admissible-bid cap: 1 (no-op) at zero backlog,
    shrinking as the virtual queue grows — only ever-cheaper clients can
    win until the time-average payout falls back under the per-round
    budget (the drift-plus-penalty knob of the long-term auction)."""
    per_round = cfg.total_reward / cfg.target_rounds
    return 1.0 / (1.0 + queue / per_round)


def longterm_select(state: SEL.SelectionState, cfg: FLConfig, key,
                    winners_impl: str = "segmented",
                    avail: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Long-term budget-feasible auction: the paper's per-cluster
    reverse auction, gated by the carried ledger — (a) a run whose
    cumulative payout has exhausted the total budget Rg selects no one,
    ever (hard long-term constraint); (b) the Lyapunov backlog caps the
    admissible bid, throttling rich rounds so the time-average payout
    converges to Rg/Nr."""
    ss = state.scheme_state
    if ss is None:
        raise ValueError(
            "scheme_select='longterm_auction' needs scheme_state — build "
            "states via rounds.synthetic_fleet / FederatedServer, or set "
            "state.scheme_state = schemes.init_scheme_state(cfg)")
    kj = SEL.k_per_cluster(cfg)
    keys = jax.random.split(key, 4)
    c, bids = A.price_round(state.clusters, state.residual,
                            state.local_sizes, state.history, kj, cfg)
    smin = SEL._sample_threshold(keys[0], state, cfg, bids)
    remaining = cfg.total_reward - ss.spent
    cap = longterm_bid_cap(cfg, ss.queue)
    eligible = ((state.local_sizes >= smin) & (c < A.INF)
                & (bids <= cap) & (remaining > 0.0))
    if avail is not None:
        eligible = eligible & avail
    cs = A.service_cost(state.local_sizes, state.history, cfg)
    win = A.cluster_winners(A.effective_bids(bids, state.strikes, cfg),
                            state.clusters, eligible, kj,
                            cfg.num_clusters, tie_break=cs,
                            impl=winners_impl)
    return win, {"bids": bids, "costs": c, "s_min": smin,
                 "revenue": A.revenue(bids, c, win)}


def _longterm_update(state, new_state, cfg, win, info, client_rewards
                     ) -> Tuple[LongTermState, Metrics]:
    """Advance the ledger by this round's ACTUAL payout (the reward
    model's per-client payments): spent is monotone non-decreasing, the
    virtual queue is max(q + spend - Rg/Nr, 0) — the standard Lyapunov
    update whose stability is exactly 'time-average spend <= budget'."""
    ss = state.scheme_state
    per_round = cfg.total_reward / cfg.target_rounds
    spend = client_rewards.sum()
    new_ss = LongTermState(
        spent=ss.spent + spend,
        queue=jnp.maximum(ss.queue + spend - per_round, 0.0),
        paid=ss.paid + client_rewards)
    return new_ss, {
        "budget_spent": spend,
        "budget_remaining": cfg.total_reward - new_ss.spent,
        "budget_queue": new_ss.queue,
    }


register(SelectionScheme(
    name="longterm_auction",
    select=longterm_select,
    init_state=_longterm_init,
    update_state=_longterm_update,
    stateful=True,
))


# ----------------------------------------------------------------------
# host-side hooks (server dynamics plumbing)
# ----------------------------------------------------------------------

def host_replacement_mask(cfg: FLConfig, host_sizes: np.ndarray
                          ) -> Optional[np.ndarray]:
    """Scheme-aware filter for the server's retry-or-replace candidate
    pool (server._resample_dropped): fedcs substitutes must themselves
    be plausibly deadline-feasible, or the replacement just converts a
    DROPPED slot into a LATE one.  Host-side and deterministic (the
    optimistic bound uses the latency model's size-driven compute term
    at the fastest straggler factor), so replacement draws stay a pure
    function of (seed, outcome stream).  None = no scheme constraint."""
    if cfg.scheme_select != "fedcs":
        return None
    sizes = host_sizes.astype(np.float64)
    compute = sizes / max(sizes.mean(), 1.0)
    # fastest profile factor: 1.0 base x the 0.9 jitter floor (energy),
    # 0.5 (uniform); 'lognormal'/'none' can reach ~0 slowdown -> 1.0x
    floor = {"energy": 0.9, "uniform": 0.5}.get(cfg.straggler_profile, 0.0)
    return compute * floor + 0.05 <= fedcs_deadline(cfg)
