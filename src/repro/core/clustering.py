"""Gradient-based client clustering (paper §III-C).

Stage-1 of Algorithm 1:
  1. every client draws ``s_mm`` samples from its local data (the *sample
     window* — the imbalance fix: every client contributes equally many
     samples to its clustering feature), repeats ``T0`` times, and averages
     the gradient of the *initial* global model over the draws;
  2. the server k-means-clusters the gradient features into J groups.

For LLM-scale models the full gradient is too large to ship; we use a fixed
random projection of the concatenated (last-block, lm-head) gradient to
``feature_dim`` — applied in column blocks so the (in_dim, feature_dim)
Gaussian is never materialized whole (DESIGN.md, fleet-scale adaptation).
For the paper's CNNs the full flattened gradient fits and is used directly.

K-means runs through a fully-jitted engine (:func:`kmeans`): incremental
k-means++ seeding (distance only to the newest centroid per pick), all
``restarts`` Lloyd runs vmapped inside one compiled program, and the fused
assign+update step dispatched per backend (Pallas kernel on TPU, the same
matmul decomposition as XLA ops elsewhere — repro.kernels.ops.lloyd_step).
The seed implementation is kept verbatim as :func:`kmeans_reference`, the
run-for-run oracle and benchmark baseline.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs.base import FLConfig
from repro.kernels import ops as KOPS


# ----------------------------------------------------------------------
# gradient features
# ----------------------------------------------------------------------

def window_indices(key, local_size: int, window: int) -> jnp.ndarray:
    """Sample-window draw: `window` indices from [0, local_size) (with
    replacement if the client has fewer samples than the window)."""
    return jax.random.randint(key, (window,), 0, local_size)


def client_gradient_feature(grad_fn: Callable, params, data_x, data_y,
                            local_size: int, cfg: FLConfig, key,
                            flatten: bool = True) -> jnp.ndarray:
    """Mean gradient of the initial model over T0 sample-window draws."""
    feats = []
    for t in range(cfg.cluster_resamples):
        k = jax.random.fold_in(key, t)
        idx = window_indices(k, local_size, cfg.sample_window)
        g = grad_fn(params, {"x": data_x[idx], "y": data_y[idx]})
        feats.append(g)
    mean_g = jax.tree.map(lambda *xs: sum(xs) / len(xs), *feats)
    if not flatten:
        return mean_g
    leaves = [x.reshape(-1) for x in jax.tree.leaves(mean_g)]
    return jnp.concatenate(leaves)


def random_projection(key, in_dim: int, out_dim: int) -> jnp.ndarray:
    """Fixed Gaussian projection (Johnson-Lindenstrauss) for LLM gradients.

    Materializes the full (in_dim, out_dim) matrix — fine for tests and
    small models; the fleet-scale path is :func:`project_features_blocked`,
    which never holds more than one column block of it."""
    return jax.random.normal(key, (in_dim, out_dim)) / jnp.sqrt(out_dim)


def project_feature(feat: jnp.ndarray, proj: Optional[jnp.ndarray]):
    return feat if proj is None else feat @ proj


@partial(jax.jit, static_argnames=("out_dim", "block"))
def project_features_blocked(key, feats: jnp.ndarray, out_dim: int,
                             block: int = 4096) -> jnp.ndarray:
    """JL projection of (N, in_dim) features to (N, out_dim) in column
    blocks: each scan step draws one (block, out_dim) Gaussian slab keyed
    on the block index and accumulates ``feats[:, b] @ G_b``, so peak
    memory is O(N·out_dim + block·out_dim) — the (in_dim, out_dim) matrix
    (100s of GB at LLM gradient widths) is never materialized."""
    n, in_dim = feats.shape
    nb = -(-in_dim // block)
    pad = nb * block - in_dim
    fp = jnp.pad(feats.astype(jnp.float32), ((0, 0), (0, pad)))
    fb = fp.reshape(n, nb, block).transpose(1, 0, 2)        # (nb, N, block)

    def body(acc, inp):
        b, xb = inp
        g = jax.random.normal(jax.random.fold_in(key, b), (block, out_dim))
        return acc + xb @ g, None

    acc, _ = jax.lax.scan(body, jnp.zeros((n, out_dim), jnp.float32),
                          (jnp.arange(nb), fb))
    return acc / jnp.sqrt(out_dim)


# ----------------------------------------------------------------------
# k-means
# ----------------------------------------------------------------------

def assign_ref(x: jnp.ndarray, centroids: jnp.ndarray) -> jnp.ndarray:
    """Pure-jnp oracle for the Pallas kmeans kernel: argmin_k ||x - c_k||²."""
    d = ((x[:, None, :] - centroids[None, :, :]) ** 2).sum(-1)
    return jnp.argmin(d, axis=1)


def _kmeanspp_init_scan(features, k, key):
    """The seed k-means++ — kept as the seeding oracle: every pick
    recomputes the distance to *all* chosen centroids through an
    (N, K, F) broadcast (O(N·K·F) time and memory per pick)."""
    n = features.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    cent0 = jnp.tile(features[first][None], (k, 1))

    def pick(carry, i):
        cent, key = carry
        d = ((features[:, None, :] - cent[None]) ** 2).sum(-1)
        col = jnp.arange(k)[None, :]
        d = jnp.where(col < i, d, jnp.inf)
        dmin = d.min(axis=1)
        key, kp = jax.random.split(key)
        p = dmin / jnp.maximum(dmin.sum(), 1e-30)
        nxt = jax.random.choice(kp, n, p=p)
        cent = cent.at[i].set(features[nxt])
        return (cent, key), None

    (cent, _), _ = jax.lax.scan(pick, (cent0, key), jnp.arange(1, k))
    return cent


def _kmeanspp_init(features, k, key):
    """Incremental k-means++: a running min-distance vector is updated
    with the distance to the *newest* centroid only — O(N·F) time and O(N)
    state per pick, no (N, K, F) intermediate. Key stream and per-centroid
    distance math match :func:`_kmeanspp_init_scan` term for term, so the
    picked seeds are identical (tested)."""
    n = features.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    c0 = features[first]
    cent0 = jnp.tile(c0[None], (k, 1))
    dmin0 = ((features - c0[None]) ** 2).sum(-1)

    def pick(carry, i):
        cent, dmin, key = carry
        key, kp = jax.random.split(key)
        p = dmin / jnp.maximum(dmin.sum(), 1e-30)
        nxt = jax.random.choice(kp, n, p=p)
        cnew = features[nxt]
        cent = cent.at[i].set(cnew)
        dmin = jnp.minimum(dmin, ((features - cnew[None]) ** 2).sum(-1))
        return (cent, dmin, key), None

    (cent, _, _), _ = jax.lax.scan(pick, (cent0, dmin0, key),
                                   jnp.arange(1, k))
    return cent


@partial(jax.jit,
         static_argnames=("k", "iters", "restarts", "assign_fn", "impl"))
def _kmeans_batched(features, key, *, k: int, iters: int, restarts: int,
                    assign_fn, impl: str):
    """One compiled program for the whole stage: incremental k-means++
    seeding, Lloyd iterations, and the restart-argmin — all ``restarts``
    runs vmapped, no Python loop and no per-restart host sync."""
    obs.jax_stats.note_trace("kmeans")   # fires at (re)trace time only
    n = features.shape[0]
    feats32 = features.astype(jnp.float32)

    def update(cent):
        if assign_fn is not None:
            # external assignment (e.g. the Pallas assign kernel under
            # test) — centroid update stays the one-hot matmul
            lab = assign_fn(features, cent)
            onehot = jax.nn.one_hot(lab, k, dtype=jnp.float32)
            counts = onehot.sum(0)
            sums = onehot.T @ feats32
        else:
            lab, _, sums, counts = KOPS.lloyd_step(features, cent, impl=impl)
        new = jnp.where(counts[:, None] > 0,
                        sums / jnp.maximum(counts[:, None], 1.0), cent)
        return new.astype(features.dtype), lab

    def one_run(kr):
        cent = _kmeanspp_init(features, k, kr)
        cent, _ = jax.lax.scan(lambda c, _: (update(c)[0], None), cent,
                               None, length=iters)
        if assign_fn is not None:
            lab = assign_fn(features, cent)
            inertia = ((feats32 - cent[lab].astype(jnp.float32)) ** 2).sum()
        else:
            lab, dist, _, _ = KOPS.lloyd_step(features, cent, impl=impl)
            inertia = dist.sum()
        return lab.astype(jnp.int32), cent, inertia

    keys = jax.vmap(lambda r: jax.random.fold_in(key, r))(
        jnp.arange(restarts))
    labs, cents, inertias = jax.vmap(one_run)(keys)
    best = jnp.argmin(inertias)      # first index on ties, like the oracle
    return labs[best], cents[best]


def kmeans(features: jnp.ndarray, k: int, key, iters: int = 25,
           assign_fn: Callable = None, restarts: int = 4,
           impl: str = "auto") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Lloyd's algorithm with k-means++ seeding and best-of-``restarts``
    (by inertia). features: (N, F). Returns (labels (N,), centroids (k,F)).

    Fully jitted: seeding + Lloyd + restart-argmin run as one compiled
    program (see :func:`_kmeans_batched`). ``impl`` selects the fused
    assign+update backend (repro.kernels.ops.lloyd_step: auto | pallas |
    ref); ``assign_fn`` overrides assignment only (testing hook)."""
    return _kmeans_batched(features, key, k=k, iters=iters,
                           restarts=restarts, assign_fn=assign_fn,
                           impl=impl)


def kmeans_reference(features: jnp.ndarray, k: int, key, iters: int = 25,
                     restarts: int = 4) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The seed implementation, kept verbatim as the run-for-run oracle and
    benchmark baseline: Python loop over restarts with a ``float(inertia)``
    host sync each, (N, K, F)-broadcast seeding and assignment, separate
    one-hot matmul update. Same per-restart key stream (fold_in) as
    :func:`kmeans`."""
    def one_run(key):
        cent = _kmeanspp_init_scan(features, k, key)

        def step(cent, _):
            lab = assign_ref(features, cent)
            onehot = jax.nn.one_hot(lab, k, dtype=features.dtype)  # (N, k)
            counts = onehot.sum(0)
            sums = onehot.T @ features
            new = jnp.where(counts[:, None] > 0,
                            sums / jnp.maximum(counts[:, None], 1.0), cent)
            return new, None

        cent, _ = jax.lax.scan(step, cent, None, length=iters)
        lab = assign_ref(features, cent)
        inertia = ((features - cent[lab]) ** 2).sum()
        return lab, cent, inertia

    best = None
    for r in range(restarts):
        lab, cent, inertia = one_run(jax.random.fold_in(key, r))
        if best is None or float(inertia) < best[2]:
            best = (lab, cent, float(inertia))
    return best[0], best[1]


# ----------------------------------------------------------------------
# full clustering stage (Algorithm 1, lines 1-8)
# ----------------------------------------------------------------------

def cluster_clients(grad_fn: Callable, params, client_data, cfg: FLConfig,
                    key, feature_kind: str = "gradient",
                    local_steps_fn: Callable = None,
                    assign_fn: Callable = None,
                    precomputed_feats: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Cluster all clients. client_data: list of (x, y) arrays per client.

    feature_kind:
      * 'gradient' — the paper's scheme (sample window + T0 mean gradients)
      * 'weights'  — the Wang et al. [2] baseline: feature = local model
        delta after one epoch of SGD (needs local_steps_fn).

    ``precomputed_feats`` (N, D) bypasses the per-client feature loop —
    the repro.sim vectorized runtime computes the same features as one
    batched program; projection and k-means still run here so both paths
    share one code path from raw features onward.

    K-means runs through the jitted batched-restart engine (the Pallas
    fused Lloyd step on TPU, its jnp twin elsewhere); oversized features
    are JL-projected in column blocks first.

    Returns (labels (N,), centroids, features).
    """
    n = cfg.num_clients
    if precomputed_feats is not None:
        feats = precomputed_feats
    else:
        with obs.span("cluster/features", feature=feature_kind,
                      runtime="reference"):
            feats = []
            for i in range(n):
                x, y = client_data[i]
                ki = jax.random.fold_in(key, i)
                if feature_kind == "gradient":
                    f = client_gradient_feature(grad_fn, params, x, y,
                                                x.shape[0], cfg, ki)
                else:
                    f = local_steps_fn(params, x, y, ki)
                feats.append(f)
            feats = jnp.stack(feats)
    if feats.shape[1] > cfg.cluster_feature_dim * 8:
        with obs.span("cluster/project", dim=int(feats.shape[1])):
            feats = project_features_blocked(jax.random.PRNGKey(1234),
                                             feats,
                                             cfg.cluster_feature_dim)
    with obs.span("cluster/kmeans", k=cfg.num_clusters):
        labels, cent = kmeans(feats, cfg.num_clusters, key,
                              assign_fn=assign_fn)
    return labels, cent, feats
