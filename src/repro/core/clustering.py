"""Gradient-based client clustering (paper §III-C).

Stage-1 of Algorithm 1:
  1. every client draws ``s_mm`` samples from its local data (the *sample
     window* — the imbalance fix: every client contributes equally many
     samples to its clustering feature), repeats ``T0`` times, and averages
     the gradient of the *initial* global model over the draws;
  2. the server k-means-clusters the gradient features into J groups.

For LLM-scale models the full gradient is too large to ship; we use a fixed
random projection of the concatenated (last-block, lm-head) gradient to
``feature_dim`` — recorded in DESIGN.md as the fleet-scale adaptation. For
the paper's CNNs the full flattened gradient fits and is used directly.

K-means' assignment step (pairwise distances + argmin) is the fleet-scale
hotspot and runs through the Pallas kernel (repro.kernels) on TPU; the pure
jnp path is used on CPU.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig


# ----------------------------------------------------------------------
# gradient features
# ----------------------------------------------------------------------

def window_indices(key, local_size: int, window: int) -> jnp.ndarray:
    """Sample-window draw: `window` indices from [0, local_size) (with
    replacement if the client has fewer samples than the window)."""
    return jax.random.randint(key, (window,), 0, local_size)


def client_gradient_feature(grad_fn: Callable, params, data_x, data_y,
                            local_size: int, cfg: FLConfig, key,
                            flatten: bool = True) -> jnp.ndarray:
    """Mean gradient of the initial model over T0 sample-window draws."""
    feats = []
    for t in range(cfg.cluster_resamples):
        k = jax.random.fold_in(key, t)
        idx = window_indices(k, local_size, cfg.sample_window)
        g = grad_fn(params, {"x": data_x[idx], "y": data_y[idx]})
        feats.append(g)
    mean_g = jax.tree.map(lambda *xs: sum(xs) / len(xs), *feats)
    if not flatten:
        return mean_g
    leaves = [x.reshape(-1) for x in jax.tree.leaves(mean_g)]
    return jnp.concatenate(leaves)


def random_projection(key, in_dim: int, out_dim: int) -> jnp.ndarray:
    """Fixed Gaussian projection (Johnson-Lindenstrauss) for LLM gradients."""
    return jax.random.normal(key, (in_dim, out_dim)) / jnp.sqrt(out_dim)


def project_feature(feat: jnp.ndarray, proj: Optional[jnp.ndarray]):
    return feat if proj is None else feat @ proj


# ----------------------------------------------------------------------
# k-means
# ----------------------------------------------------------------------

def assign_ref(x: jnp.ndarray, centroids: jnp.ndarray) -> jnp.ndarray:
    """Pure-jnp oracle for the Pallas kmeans kernel: argmin_k ||x - c_k||²."""
    d = ((x[:, None, :] - centroids[None, :, :]) ** 2).sum(-1)
    return jnp.argmin(d, axis=1)


def _kmeanspp_init(features, k, key):
    """k-means++ seeding: each next centroid sampled with probability
    proportional to the squared distance from the nearest chosen one."""
    n = features.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    cent0 = jnp.tile(features[first][None], (k, 1))

    def pick(carry, i):
        cent, key = carry
        d = ((features[:, None, :] - cent[None]) ** 2).sum(-1)
        col = jnp.arange(k)[None, :]
        d = jnp.where(col < i, d, jnp.inf)
        dmin = d.min(axis=1)
        key, kp = jax.random.split(key)
        p = dmin / jnp.maximum(dmin.sum(), 1e-30)
        nxt = jax.random.choice(kp, n, p=p)
        cent = cent.at[i].set(features[nxt])
        return (cent, key), None

    (cent, _), _ = jax.lax.scan(pick, (cent0, key), jnp.arange(1, k))
    return cent


def kmeans(features: jnp.ndarray, k: int, key, iters: int = 25,
           assign_fn: Callable = None,
           restarts: int = 4) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Lloyd's algorithm with k-means++ seeding and best-of-``restarts``
    (by inertia). features: (N, F). Returns (labels (N,), centroids (k,F))."""
    n = features.shape[0]
    if assign_fn is None:
        assign_fn = assign_ref

    def one_run(key):
        cent = _kmeanspp_init(features, k, key)

        def step(cent, _):
            lab = assign_fn(features, cent)
            onehot = jax.nn.one_hot(lab, k, dtype=features.dtype)  # (N, k)
            counts = onehot.sum(0)
            sums = onehot.T @ features
            new = jnp.where(counts[:, None] > 0,
                            sums / jnp.maximum(counts[:, None], 1.0), cent)
            return new, None

        cent, _ = jax.lax.scan(step, cent, None, length=iters)
        lab = assign_fn(features, cent)
        inertia = ((features - cent[lab]) ** 2).sum()
        return lab, cent, inertia

    best = None
    for r in range(restarts):
        lab, cent, inertia = one_run(jax.random.fold_in(key, r))
        if best is None or float(inertia) < best[2]:
            best = (lab, cent, float(inertia))
    return best[0], best[1]


# ----------------------------------------------------------------------
# full clustering stage (Algorithm 1, lines 1-8)
# ----------------------------------------------------------------------

def cluster_clients(grad_fn: Callable, params, client_data, cfg: FLConfig,
                    key, feature_kind: str = "gradient",
                    local_steps_fn: Callable = None,
                    assign_fn: Callable = None,
                    precomputed_feats: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Cluster all clients. client_data: list of (x, y) arrays per client.

    feature_kind:
      * 'gradient' — the paper's scheme (sample window + T0 mean gradients)
      * 'weights'  — the Wang et al. [2] baseline: feature = local model
        delta after one epoch of SGD (needs local_steps_fn).

    ``precomputed_feats`` (N, D) bypasses the per-client feature loop —
    the repro.sim vectorized runtime computes the same features as one
    batched program; projection and k-means still run here so both paths
    share one code path from raw features onward.

    Returns (labels (N,), centroids, features).
    """
    n = cfg.num_clients
    proj = None
    if precomputed_feats is not None:
        feats = precomputed_feats
        if feats.shape[1] > cfg.cluster_feature_dim * 8:
            proj = random_projection(jax.random.PRNGKey(1234),
                                     feats.shape[1], cfg.cluster_feature_dim)
    else:
        feats = []
        for i in range(n):
            x, y = client_data[i]
            ki = jax.random.fold_in(key, i)
            if feature_kind == "gradient":
                f = client_gradient_feature(grad_fn, params, x, y,
                                            x.shape[0], cfg, ki)
            else:
                f = local_steps_fn(params, x, y, ki)
            if proj is None and f.shape[0] > cfg.cluster_feature_dim * 8:
                proj = random_projection(jax.random.PRNGKey(1234), f.shape[0],
                                         cfg.cluster_feature_dim)
            feats.append(f)
        feats = jnp.stack(feats)
    if proj is not None:
        feats = feats @ proj
    labels, cent = kmeans(feats, cfg.num_clusters, key,
                          assign_fn=assign_fn)
    return labels, cent, feats
