"""Federated server: the full Algorithm 1 loop.

Stage 1  (once)    : gradient/weight clustering of all clients.
Stage 2  (per round): cost -> Nash bids -> s_min threshold -> per-cluster
                      winners (or the paper's baselines' random picks),
                      rewards, energy/history update and round metrics —
                      fused into ONE jitted program per round
                      (repro.core.rounds), one host transfer for logging.
Stage 3  (per round): winners run I local epochs (FedAvg local SGD, or
                      FedProx with the proximal term), server aggregates
                      w_{t+1} = sum_k p_k w^k_{t+1}, energy/history update.

Stage-3 execution is delegated to a pluggable :mod:`repro.sim` cohort
runtime (``cfg.runtime``): ``sequential`` runs clients one by one (the
paper's own execution model, kept as the reference oracle), ``vectorized``
runs the whole cohort as one compiled vmap/scan program per size bucket,
``sharded`` maps it over the cohort mesh, and ``device`` keeps the whole
fleet resident on device (repro.sim.fleet) so per-round assembly is an
on-device gather; the *launch* layer additionally maps cohorts onto mesh
axes for the TPU-scale path — see repro/launch/train.py.

The round loop is ASYNC: each round's control-plane metrics (and its
eval scalars, computed every ``cfg.eval_every`` rounds by one fused
jitted accuracy+loss program) stay on device in a pending buffer, and
round t+1's selection/training dispatch while round t's fetches are
still in flight.  One batched ``device_get`` drains the buffer at
logging boundaries (verbose prints, ``run_round`` returns, end of run) —
the only unconditional per-round host transfer left is the winner mask,
which stage-3's host-seeded shuffle rng genuinely needs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import FLConfig
from repro.core import clustering as CL
from repro.core import energy as EN
from repro.core import rounds as RND
from repro.core import selection as SEL
from repro.core.adapters import ModelAdapter
from repro.optim import apply_updates, sgd
from repro.sim.runtime import make_runtime


@dataclass
class RoundLog:
    round: int
    selected: np.ndarray
    test_acc: float            # NaN on rounds skipped by cfg.eval_every
    test_loss: float
    energy_std: float
    mean_bid: float
    server_reward: float
    client_reward_sum: float
    vds_gap: float


@dataclass
class _PendingRound:
    """A dispatched round whose host fetches haven't happened yet:
    ``metrics`` is the round step's on-device scalar dict, ``eval_pair``
    the fused (accuracy, loss) device scalars or None off-cadence."""

    round: int
    selected: np.ndarray
    metrics: Any
    eval_pair: Optional[Any]


class FederatedServer:
    def __init__(self, cfg: FLConfig, adapter: ModelAdapter,
                 x: np.ndarray, y: np.ndarray, clients,
                 test_batch: Dict[str, np.ndarray],
                 assign_fn=None, seed: Optional[int] = None):
        self.cfg = cfg
        self.adapter = adapter
        self.x, self.y = x, y
        self.clients = clients
        self.test_batch = test_batch
        self.assign_fn = assign_fn
        self.key = jax.random.PRNGKey(cfg.seed if seed is None else seed)
        self.params = adapter.init(self._next_key())
        self.logs: List[RoundLog] = []
        self.runtime = make_runtime(cfg, adapter, x, y, clients)

        sizes = jnp.asarray([c.size for c in clients], jnp.int32)
        self.state = SEL.SelectionState(
            clusters=jnp.zeros((cfg.num_clients,), jnp.int32),
            residual=EN.init_energy(cfg, self._next_key()),
            history=jnp.zeros((cfg.num_clients,), jnp.int32),
            local_sizes=sizes,
        )
        from repro.core.virtual_dataset import client_count_histograms
        from repro.data.partition import global_histogram
        self.global_hist = global_histogram(y, cfg.num_classes)
        self.client_labels = [y[c.train_idx] for c in clients]
        self.total_client_reward = 0.0
        # fused round control plane: one jitted (state, key) -> (state,
        # win, metrics) program; metrics (energy std, mean winning bid,
        # reward sums, vds-gap) are computed on device so run_round does
        # one host transfer for the whole control plane.
        self._round_step = RND.make_round_step(
            cfg, client_count_histograms(self.client_labels,
                                         cfg.num_classes),
            self.global_hist)
        # host mirror of participation counts: stage-3 shuffle seeding
        # reads history per winner, which on the device array cost one
        # int(history[i]) sync per client per round.
        self._host_history = np.zeros((cfg.num_clients,), np.int64)
        # fused eval: accuracy + loss as ONE jitted program (the two
        # nested jits inline), so an eval round costs one deferred fetch
        # instead of two blocking ones; the test batch is committed to
        # device once instead of being re-transferred per round.
        def _eval(p, b):
            obs.jax_stats.note_trace("eval")     # trace-time side effect
            return adapter.accuracy(p, b), adapter.loss(p, b)

        self._eval_step = jax.jit(_eval)
        self._test_dev = obs.device_put(test_batch)
        self._pending: List[_PendingRound] = []
        # last eval pair actually drained (progress prints show this
        # instead of forcing an off-cadence eval — see run())
        self._last_eval = (float("nan"), float("nan"))

    # ------------------------------------------------------------------
    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    # ------------------------------------------------------------------
    def cluster(self):
        """Stage 1: cluster clients (scheme-dependent feature).

        With the default ``assign_fn=None`` k-means routes through the
        fused clustering engine (repro.core.clustering.kmeans): one jit
        for seeding + Lloyd + restart-argmin, the Pallas assign+update
        kernel on TPU and its jnp twin elsewhere; ``assign_fn`` overrides
        assignment only (testing hook)."""
        cfg = self.cfg
        if cfg.scheme == "random":
            return
        feature_kind = ("weights" if cfg.scheme == "weights_cluster_random"
                        else "gradient")
        data = [(self.x[c.train_idx], self.y[c.train_idx])
                for c in self.clients]

        def local_steps_fn(params, x, y, key):
            # Wang et al. [2] feature: local model delta after 1 epoch SGD
            init, upd = sgd(cfg.lr)
            opt = init(params)
            p = params
            bs = min(32, x.shape[0])
            for i in range(0, x.shape[0] - bs + 1, bs):
                b = {"x": x[i:i + bs], "y": y[i:i + bs]}
                g = self.adapter.grad(p, b)
                u, opt = upd(g, opt, p)
                p = apply_updates(p, u)
            delta = jax.tree.map(lambda a, b: (a - b).reshape(-1), p, params)
            return jnp.concatenate(jax.tree.leaves(delta))

        key = self._next_key()
        # the runtime may compute the whole feature pass as one batched
        # program (vectorized backend); None -> reference per-client loop
        feats = self.runtime.cluster_features(self.params, key, feature_kind)
        labels, cent, feats = CL.cluster_clients(
            self.adapter.grad, self.params, data, cfg, key,
            feature_kind=feature_kind, local_steps_fn=local_steps_fn,
            assign_fn=self.assign_fn, precomputed_feats=feats)
        self.state = SEL.SelectionState(
            clusters=labels.astype(jnp.int32), residual=self.state.residual,
            history=self.state.history, local_sizes=self.state.local_sizes)

    # ------------------------------------------------------------------
    def local_train(self, client_idx: int, global_params):
        return self.runtime.train_client(
            global_params, client_idx, int(self._host_history[client_idx]))

    # ------------------------------------------------------------------
    def _eval_due(self, t: int, final: bool = False) -> bool:
        return final or self.cfg.eval_every <= 1 \
            or t % self.cfg.eval_every == 0

    def _dispatch_round(self, t: int, eval_now: bool) -> None:
        """Dispatch one FL round without fetching its results.  The whole
        stage-2 control plane (selection, rewards, energy/history update,
        round metrics) is one jitted call (repro.core.rounds
        .make_round_step); only the winner mask is fetched — stage-3's
        host-seeded shuffle rng needs it — while the metric scalars (and
        the fused eval pair, when due) stay on device in the pending
        buffer until the next logging boundary."""
        with obs.span("round/dispatch", round=t):
            with obs.span("round/select", round=t):
                new_state, win, metrics = self._round_step(self.state,
                                                           self._next_key())
                # the one unconditional per-round fetch (explicit, counted)
                win_np = obs.device_get(win)
                sel_idx = np.nonzero(win_np)[0]

            # stage 3: local training + aggregation (cohort runtime
            # backend); shuffle seeds read the pre-round host history
            # mirror
            with obs.span("round/train", round=t,
                          cohort=int(sel_idx.size)):
                new_params = self.runtime.train_cohort(
                    self.params, sel_idx, self._host_history)
            if new_params is not None:
                self.params = new_params

            self.state = new_state
            self._host_history[sel_idx] += 1
            if eval_now:
                with obs.span("round/eval", round=t):
                    ev = self._eval_step(self.params, self._test_dev)
            else:
                ev = None
            self._pending.append(_PendingRound(
                round=t, selected=sel_idx, metrics=metrics, eval_pair=ev))

    def _flush_pending(self) -> None:
        """Drain the pending buffer with ONE batched device_get and turn
        every entry into a RoundLog (deferring the fetch cannot change
        the values — they were computed by the same programs)."""
        if not self._pending:
            return
        with obs.span("round/drain", rounds=len(self._pending),
                      first=self._pending[0].round):
            fetched = obs.device_get(
                [(p.metrics, p.eval_pair) for p in self._pending])
        for p, (m, ev) in zip(self._pending, fetched):
            acc, loss = ((float(ev[0]), float(ev[1])) if ev is not None
                         else (float("nan"), float("nan")))
            if ev is not None:
                self._last_eval = (acc, loss)
            self.total_client_reward += float(m["client_reward_sum"])
            self.logs.append(RoundLog(
                round=p.round, selected=p.selected, test_acc=acc,
                test_loss=loss, energy_std=float(m["energy_std"]),
                mean_bid=float(m["mean_bid"]),
                server_reward=float(m["server_reward"]),
                client_reward_sum=float(m["client_reward_sum"]),
                vds_gap=float(m["vds_gap"])))
            # per-round series row: every scalar is already a host float
            # from the batched fetch above — recording adds no sync
            obs.OBS.record_round(
                p.round, test_acc=acc, test_loss=loss,
                energy_std=float(m["energy_std"]),
                mean_bid=float(m["mean_bid"]),
                server_reward=float(m["server_reward"]),
                client_reward_sum=float(m["client_reward_sum"]),
                vds_gap=float(m["vds_gap"]),
                num_selected=int(p.selected.size))
        self._pending.clear()
        obs.flush()        # the logging boundary: sinks see I/O only here

    def run_round(self, t: int) -> RoundLog:
        """One synchronous FL round (dispatch + immediate flush) — the
        single-round API; the async pipeline lives in :meth:`run`."""
        self._dispatch_round(t, self._eval_due(t))
        self._flush_pending()
        return self.logs[-1]

    # ------------------------------------------------------------------
    def run(self, rounds: Optional[int] = None, verbose: bool = False,
            audit_sync: bool = False, audit_warm_rounds: int = 2):
        """The async round loop.  ``verbose`` prints a progress line
        every 5 rounds showing the *last drained* eval (NaN until one
        drains) — verbosity must never change the measured eval cadence
        (it used to force an eval at every print boundary, so logs and
        params depended on the flag; regression-tested in
        tests/test_obs.py).  ``audit_sync`` wraps every dispatch from
        round ``audit_warm_rounds`` on in the transfer-guard sync
        auditor: an implicit host transfer inside the warm loop raises
        at the offending op (obs.sync_audit)."""
        with obs.span("run/cluster", scheme=self.cfg.scheme):
            self.cluster()
        warmup = getattr(self.runtime, "warmup", None)
        if warmup is not None:    # device runtime: compile every class
            with obs.span("run/warmup"):
                warmup(self.params)
        T = rounds if rounds is not None else self.cfg.rounds
        for t in range(T):
            printing = verbose and (t % 5 == 0 or t == T - 1)
            if audit_sync and t >= audit_warm_rounds:
                with obs.sync_audit():
                    self._dispatch_round(t, self._eval_due(t,
                                                           final=t == T - 1))
            else:
                self._dispatch_round(t, self._eval_due(t,
                                                       final=t == T - 1))
            if printing:
                self._flush_pending()
                log = self.logs[-1]
                acc, loss = self._last_eval
                obs.log(f"  round {t:3d} acc={acc:.3f} "
                        f"loss={loss:.3f} "
                        f"E_std={log.energy_std:.3f} "
                        f"bid={log.mean_bid:.3f} "
                        f"vds_gap={log.vds_gap:.3f}")
        self._flush_pending()
        return self.logs
