"""Federated server: the full Algorithm 1 loop.

Stage 1  (once)    : gradient/weight clustering of all clients.
Stage 2  (per round): cost -> Nash bids -> s_min threshold -> per-cluster
                      winners (or the paper's baselines' random picks),
                      rewards, energy/history update and round metrics —
                      fused into ONE jitted program per round
                      (repro.core.rounds), one host transfer for logging.
Stage 3  (per round): winners run I local epochs (FedAvg local SGD, or
                      FedProx with the proximal term), server aggregates
                      w_{t+1} = sum_k p_k w^k_{t+1}, energy/history update.

Stage-3 execution is delegated to a pluggable :mod:`repro.sim` cohort
runtime (``cfg.runtime``): ``sequential`` runs clients one by one (the
paper's own execution model, kept as the reference oracle), ``vectorized``
runs the whole cohort as one compiled vmap/scan program per size bucket;
the *launch* layer additionally maps cohorts onto mesh axes for the
TPU-scale path — see repro/launch/train.py.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import clustering as CL
from repro.core import energy as EN
from repro.core import rounds as RND
from repro.core import selection as SEL
from repro.core.adapters import ModelAdapter
from repro.optim import apply_updates, sgd
from repro.sim.runtime import make_runtime


@dataclass
class RoundLog:
    round: int
    selected: np.ndarray
    test_acc: float
    test_loss: float
    energy_std: float
    mean_bid: float
    server_reward: float
    client_reward_sum: float
    vds_gap: float


class FederatedServer:
    def __init__(self, cfg: FLConfig, adapter: ModelAdapter,
                 x: np.ndarray, y: np.ndarray, clients,
                 test_batch: Dict[str, np.ndarray],
                 assign_fn=None, seed: Optional[int] = None):
        self.cfg = cfg
        self.adapter = adapter
        self.x, self.y = x, y
        self.clients = clients
        self.test_batch = test_batch
        self.assign_fn = assign_fn
        self.key = jax.random.PRNGKey(cfg.seed if seed is None else seed)
        self.params = adapter.init(self._next_key())
        self.logs: List[RoundLog] = []
        self.runtime = make_runtime(cfg, adapter, x, y, clients)

        sizes = jnp.asarray([c.size for c in clients], jnp.int32)
        self.state = SEL.SelectionState(
            clusters=jnp.zeros((cfg.num_clients,), jnp.int32),
            residual=EN.init_energy(cfg, self._next_key()),
            history=jnp.zeros((cfg.num_clients,), jnp.int32),
            local_sizes=sizes,
        )
        from repro.core.virtual_dataset import client_count_histograms
        from repro.data.partition import global_histogram
        self.global_hist = global_histogram(y, cfg.num_classes)
        self.client_labels = [y[c.train_idx] for c in clients]
        self.total_client_reward = 0.0
        # fused round control plane: one jitted (state, key) -> (state,
        # win, metrics) program; metrics (energy std, mean winning bid,
        # reward sums, vds-gap) are computed on device so run_round does
        # one host transfer for the whole control plane.
        self._round_step = RND.make_round_step(
            cfg, client_count_histograms(self.client_labels,
                                         cfg.num_classes),
            self.global_hist)
        # host mirror of participation counts: stage-3 shuffle seeding
        # reads history per winner, which on the device array cost one
        # int(history[i]) sync per client per round.
        self._host_history = np.zeros((cfg.num_clients,), np.int64)

    # ------------------------------------------------------------------
    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    # ------------------------------------------------------------------
    def cluster(self):
        """Stage 1: cluster clients (scheme-dependent feature).

        With the default ``assign_fn=None`` k-means routes through the
        fused clustering engine (repro.core.clustering.kmeans): one jit
        for seeding + Lloyd + restart-argmin, the Pallas assign+update
        kernel on TPU and its jnp twin elsewhere; ``assign_fn`` overrides
        assignment only (testing hook)."""
        cfg = self.cfg
        if cfg.scheme == "random":
            return
        feature_kind = ("weights" if cfg.scheme == "weights_cluster_random"
                        else "gradient")
        data = [(self.x[c.train_idx], self.y[c.train_idx])
                for c in self.clients]

        def local_steps_fn(params, x, y, key):
            # Wang et al. [2] feature: local model delta after 1 epoch SGD
            init, upd = sgd(cfg.lr)
            opt = init(params)
            p = params
            bs = min(32, x.shape[0])
            for i in range(0, x.shape[0] - bs + 1, bs):
                b = {"x": x[i:i + bs], "y": y[i:i + bs]}
                g = self.adapter.grad(p, b)
                u, opt = upd(g, opt, p)
                p = apply_updates(p, u)
            delta = jax.tree.map(lambda a, b: (a - b).reshape(-1), p, params)
            return jnp.concatenate(jax.tree.leaves(delta))

        key = self._next_key()
        # the runtime may compute the whole feature pass as one batched
        # program (vectorized backend); None -> reference per-client loop
        feats = self.runtime.cluster_features(self.params, key, feature_kind)
        labels, cent, feats = CL.cluster_clients(
            self.adapter.grad, self.params, data, cfg, key,
            feature_kind=feature_kind, local_steps_fn=local_steps_fn,
            assign_fn=self.assign_fn, precomputed_feats=feats)
        self.state = SEL.SelectionState(
            clusters=labels.astype(jnp.int32), residual=self.state.residual,
            history=self.state.history, local_sizes=self.state.local_sizes)

    # ------------------------------------------------------------------
    def local_train(self, client_idx: int, global_params):
        return self.runtime.train_client(
            global_params, client_idx, int(self._host_history[client_idx]))

    # ------------------------------------------------------------------
    def run_round(self, t: int) -> RoundLog:
        """One FL round. The whole stage-2 control plane (selection,
        rewards, energy/history update, round metrics) is one jitted call
        (repro.core.rounds.make_round_step); the winner mask and metric
        scalars come back in a single host transfer, stage-3 training then
        overlaps the already-dispatched state update."""
        cfg = self.cfg
        new_state, win, metrics = self._round_step(self.state,
                                                   self._next_key())
        win_np, m = jax.device_get((win, metrics))
        sel_idx = np.nonzero(win_np)[0]

        # stage 3: local training + aggregation (cohort runtime backend);
        # shuffle seeds read the pre-round host history mirror
        new_params = self.runtime.train_cohort(
            self.params, sel_idx, self._host_history)
        if new_params is not None:
            self.params = new_params

        self.state = new_state
        self._host_history[sel_idx] += 1
        self.total_client_reward += float(m["client_reward_sum"])

        # evaluation (model quality — the only other host fetches)
        acc = float(self.adapter.accuracy(self.params, self.test_batch))
        loss = float(self.adapter.loss(self.params, self.test_batch))
        log = RoundLog(
            round=t, selected=sel_idx, test_acc=acc, test_loss=loss,
            energy_std=float(m["energy_std"]),
            mean_bid=float(m["mean_bid"]),
            server_reward=float(m["server_reward"]),
            client_reward_sum=float(m["client_reward_sum"]),
            vds_gap=float(m["vds_gap"]))
        self.logs.append(log)
        return log

    # ------------------------------------------------------------------
    def run(self, rounds: Optional[int] = None, verbose: bool = False):
        self.cluster()
        T = rounds if rounds is not None else self.cfg.rounds
        for t in range(T):
            log = self.run_round(t)
            if verbose and (t % 5 == 0 or t == T - 1):
                print(f"  round {t:3d} acc={log.test_acc:.3f} "
                      f"loss={log.test_loss:.3f} "
                      f"E_std={log.energy_std:.3f} bid={log.mean_bid:.3f} "
                      f"vds_gap={log.vds_gap:.3f}")
        return self.logs
