"""Federated server: the full Algorithm 1 loop.

Stage 1  (once)    : gradient/weight clustering of all clients.
Stage 2  (per round): cost -> Nash bids -> s_min threshold -> per-cluster
                      winners (or the paper's baselines' random picks),
                      rewards, energy/history update and round metrics —
                      fused into ONE jitted program per round
                      (repro.core.rounds), one host transfer for logging.
Stage 3  (per round): winners run I local epochs (FedAvg local SGD, or
                      FedProx with the proximal term), server aggregates
                      w_{t+1} = sum_k p_k w^k_{t+1}, energy/history update.

Stage-3 execution is delegated to a pluggable :mod:`repro.sim` cohort
runtime (``cfg.runtime``): ``sequential`` runs clients one by one (the
paper's own execution model, kept as the reference oracle), ``vectorized``
runs the whole cohort as one compiled vmap/scan program per size bucket,
``sharded`` maps it over the cohort mesh, and ``device`` keeps the whole
fleet resident on device (repro.sim.fleet) so per-round assembly is an
on-device gather; the *launch* layer additionally maps cohorts onto mesh
axes for the TPU-scale path — see repro/launch/train.py.

The round loop is ASYNC: each round's control-plane metrics (and its
eval scalars, computed every ``cfg.eval_every`` rounds by one fused
jitted accuracy+loss program) stay on device in a pending buffer, and
round t+1's selection/training dispatch while round t's fetches are
still in flight.  One batched ``device_get`` drains the buffer at
logging boundaries (verbose prints, ``run_round`` returns, end of run) —
the only unconditional per-round host transfer left is the winner mask,
which stage-3's host-seeded shuffle rng genuinely needs.

With fleet dynamics on (``cfg.dynamics_enabled`` — any churn or a
positive deadline) the fused round step additionally runs the
repro.sim.dynamics fault model, and the aggregation path degrades
gracefully instead of assuming a full cohort: only COMPLETED winners
(plus retry-or-replace substitutes for DROPPED ones) aggregate
synchronously — FedAvg re-weights over the survivors automatically
because the cohort runtimes normalize within whatever index set they
are handed; a zero-survivor round leaves the params untouched and logs
a ``round/empty`` dynamics event (never a 0/0).  Under ``--aggregation
buffered`` LATE winners still train, but their update lands in a
device-resident buffer as a staleness-stamped delta and folds into the
global model FedBuff-style at goal-count or timeout boundaries
(``round/buffer_fold`` spans).  With dynamics off both aggregation
modes take the exact pre-dynamics code path — the synchronous oracle —
so churn-0 runs stay bit-identical (tests/test_dynamics.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import FLConfig
from repro.core import clustering as CL
from repro.core import energy as EN
from repro.core import rounds as RND
from repro.core import selection as SEL
from repro.core.adapters import ModelAdapter
from repro.optim import apply_updates, sgd
from repro.sim import dynamics as DYN
from repro.sim.runtime import make_runtime


@dataclass
class RoundLog:
    round: int
    selected: np.ndarray
    test_acc: float            # NaN on rounds skipped by cfg.eval_every
    test_loss: float
    energy_std: float
    mean_bid: float
    server_reward: float
    client_reward_sum: float
    vds_gap: float


@dataclass
class _PendingRound:
    """A dispatched round whose host fetches haven't happened yet:
    ``metrics`` is the round step's on-device scalar dict, ``eval_pair``
    the fused (accuracy, loss) device scalars or None off-cadence,
    ``dyn`` the host-side dynamics scalars (replacements, buffer depth)
    or None with dynamics off."""

    round: int
    selected: np.ndarray
    metrics: Any
    eval_pair: Optional[Any]
    dyn: Optional[Dict[str, float]] = None


@dataclass
class _BufferedUpdate:
    """One late update parked in the device-resident FedBuff buffer:
    ``delta`` is the late sub-cohort's aggregated param delta (vs the
    globals it trained from) as a device tree, ``mass`` its data mass
    (sum of local sizes — the FedAvg numerator it would have carried),
    ``round`` the dispatch round and ``arrival`` the first round the
    server can fold it (dispatch + 1: late means after the deadline)."""

    delta: Any
    mass: float
    round: int
    arrival: int

# device metric keys the dynamics round step adds; drained with the same
# batched fetch as the base metrics and mirrored into the round series
_DYN_METRIC_KEYS = ("num_completed", "num_late", "num_dropped",
                    "staleness_mean", "staleness_max", "mean_latency",
                    "num_avail")


class FederatedServer:
    def __init__(self, cfg: FLConfig, adapter: ModelAdapter,
                 x: np.ndarray, y: np.ndarray, clients,
                 test_batch: Dict[str, np.ndarray],
                 assign_fn=None, seed: Optional[int] = None):
        self.cfg = cfg
        self.adapter = adapter
        self.x, self.y = x, y
        self.clients = clients
        self.test_batch = test_batch
        self.assign_fn = assign_fn
        self.key = jax.random.PRNGKey(cfg.seed if seed is None else seed)
        self.params = adapter.init(self._next_key())
        self.logs: List[RoundLog] = []
        self.runtime = make_runtime(cfg, adapter, x, y, clients)

        sizes = jnp.asarray([c.size for c in clients], jnp.int32)
        self.dynamics = cfg.dynamics_enabled
        self.state = SEL.SelectionState(
            clusters=jnp.zeros((cfg.num_clients,), jnp.int32),
            residual=EN.init_energy(cfg, self._next_key()),
            history=jnp.zeros((cfg.num_clients,), jnp.int32),
            local_sizes=sizes,
            # None with dynamics off: the field must not exist as an
            # array leaf or the dynamics-free round traces would change
            staleness=(jnp.zeros((cfg.num_clients,), jnp.int32)
                       if self.dynamics else None),
        )
        from repro.core.virtual_dataset import client_count_histograms
        from repro.data.partition import global_histogram
        self.global_hist = global_histogram(y, cfg.num_classes)
        self.client_labels = [y[c.train_idx] for c in clients]
        self.total_client_reward = 0.0
        # fused round control plane: one jitted (state, key) -> (state,
        # win, metrics) program; metrics (energy std, mean winning bid,
        # reward sums, vds-gap) are computed on device so run_round does
        # one host transfer for the whole control plane.
        self._round_step = RND.make_round_step(
            cfg, client_count_histograms(self.client_labels,
                                         cfg.num_classes),
            self.global_hist, dynamics=self.dynamics)
        if self.dynamics:
            # the DEDICATED dynamics chain: split off its own root so
            # churn-0 runs consume the selection chain identically
            self._dyn_key = DYN.dynamics_key(cfg)
            self.dyn_state = DYN.init_dynamics(cfg)
            # host mirrors the replacement sampler reads: round-start
            # availability and (after stage 1) cluster ids
            self._host_avail = np.ones((cfg.num_clients,), bool)
            self._host_clusters = np.zeros((cfg.num_clients,), np.int64)
            self._host_sizes = np.asarray([c.size for c in clients],
                                          np.int64)
            # replacement draws come from their own host rng chain, so
            # they are a pure function of (seed, outcome stream) and
            # identical across cohort runtimes
            self._dyn_rng = np.random.default_rng(
                np.uint32(cfg.seed) + 0x5D7A)
            self.outcome_log: List[np.ndarray] = []   # per-round winner codes
            self._late_buffer: List[_BufferedUpdate] = []
            self._delta_step = jax.jit(
                lambda new, old: jax.tree.map(jnp.subtract, new, old))
            self._fold_one = jax.jit(
                lambda p, d, c: jax.tree.map(lambda a, b: a + c * b, p, d))
        # host mirror of participation counts: stage-3 shuffle seeding
        # reads history per winner, which on the device array cost one
        # int(history[i]) sync per client per round.
        self._host_history = np.zeros((cfg.num_clients,), np.int64)
        # fused eval: accuracy + loss as ONE jitted program (the two
        # nested jits inline), so an eval round costs one deferred fetch
        # instead of two blocking ones; the test batch is committed to
        # device once instead of being re-transferred per round.
        def _eval(p, b):
            obs.jax_stats.note_trace("eval")     # trace-time side effect
            return adapter.accuracy(p, b), adapter.loss(p, b)

        self._eval_step = jax.jit(_eval)
        self._test_dev = obs.device_put(test_batch)
        self._pending: List[_PendingRound] = []
        # last eval pair actually drained (progress prints show this
        # instead of forcing an off-cadence eval — see run())
        self._last_eval = (float("nan"), float("nan"))

    # ------------------------------------------------------------------
    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def _next_dyn_key(self):
        self._dyn_key, k = jax.random.split(self._dyn_key)
        return k

    # ------------------------------------------------------------------
    def cluster(self):
        """Stage 1: cluster clients (scheme-dependent feature).

        With the default ``assign_fn=None`` k-means routes through the
        fused clustering engine (repro.core.clustering.kmeans): one jit
        for seeding + Lloyd + restart-argmin, the Pallas assign+update
        kernel on TPU and its jnp twin elsewhere; ``assign_fn`` overrides
        assignment only (testing hook)."""
        cfg = self.cfg
        if cfg.scheme == "random":
            return
        feature_kind = ("weights" if cfg.scheme == "weights_cluster_random"
                        else "gradient")
        data = [(self.x[c.train_idx], self.y[c.train_idx])
                for c in self.clients]

        def local_steps_fn(params, x, y, key):
            # Wang et al. [2] feature: local model delta after 1 epoch SGD
            init, upd = sgd(cfg.lr)
            opt = init(params)
            p = params
            bs = min(32, x.shape[0])
            for i in range(0, x.shape[0] - bs + 1, bs):
                b = {"x": x[i:i + bs], "y": y[i:i + bs]}
                g = self.adapter.grad(p, b)
                u, opt = upd(g, opt, p)
                p = apply_updates(p, u)
            delta = jax.tree.map(lambda a, b: (a - b).reshape(-1), p, params)
            return jnp.concatenate(jax.tree.leaves(delta))

        key = self._next_key()
        # the runtime may compute the whole feature pass as one batched
        # program (vectorized backend); None -> reference per-client loop
        feats = self.runtime.cluster_features(self.params, key, feature_kind)
        labels, cent, feats = CL.cluster_clients(
            self.adapter.grad, self.params, data, cfg, key,
            feature_kind=feature_kind, local_steps_fn=local_steps_fn,
            assign_fn=self.assign_fn, precomputed_feats=feats)
        self.state = SEL.SelectionState(
            clusters=labels.astype(jnp.int32), residual=self.state.residual,
            history=self.state.history, local_sizes=self.state.local_sizes,
            staleness=self.state.staleness)
        if self.dynamics:
            self._host_clusters = np.asarray(obs.device_get(labels),
                                             np.int64)

    # ------------------------------------------------------------------
    def local_train(self, client_idx: int, global_params):
        return self.runtime.train_client(
            global_params, client_idx, int(self._host_history[client_idx]))

    # ------------------------------------------------------------------
    def _eval_due(self, t: int, final: bool = False) -> bool:
        return final or self.cfg.eval_every <= 1 \
            or t % self.cfg.eval_every == 0

    def _dispatch_round(self, t: int, eval_now: bool,
                        final: bool = False) -> None:
        """Dispatch one FL round without fetching its results.  The whole
        stage-2 control plane (selection, rewards, energy/history update,
        round metrics) is one jitted call (repro.core.rounds
        .make_round_step); only the winner mask is fetched — stage-3's
        host-seeded shuffle rng needs it — while the metric scalars (and
        the fused eval pair, when due) stay on device in the pending
        buffer until the next logging boundary.  With fleet dynamics on
        the fused step also runs the fault model and dispatch degrades
        gracefully over the outcome mask (:meth:`_dispatch_round_dyn`)."""
        if self.dynamics:
            return self._dispatch_round_dyn(t, eval_now, final)
        with obs.span("round/dispatch", round=t):
            with obs.span("round/select", round=t):
                new_state, win, metrics = self._round_step(self.state,
                                                           self._next_key())
                # the one unconditional per-round fetch (explicit, counted)
                win_np = obs.device_get(win)
                sel_idx = np.nonzero(win_np)[0]

            # stage 3: local training + aggregation (cohort runtime
            # backend); shuffle seeds read the pre-round host history
            # mirror
            with obs.span("round/train", round=t,
                          cohort=int(sel_idx.size)):
                new_params = self.runtime.train_cohort(
                    self.params, sel_idx, self._host_history)
            if new_params is not None:
                self.params = new_params
            else:
                # zero-winner (or all-zero-size) round: the runtimes
                # return None instead of a 0/0 aggregate — params pass
                # through unchanged and the event is visible in the log
                self._log_empty_round(t)

            self.state = new_state
            self._host_history[sel_idx] += 1
            if eval_now:
                with obs.span("round/eval", round=t):
                    ev = self._eval_step(self.params, self._test_dev)
            else:
                ev = None
            self._pending.append(_PendingRound(
                round=t, selected=sel_idx, metrics=metrics, eval_pair=ev))

    # -- fleet dynamics ------------------------------------------------
    def _log_empty_round(self, t: int) -> None:
        """A round whose synchronous aggregate had no survivors: params
        pass through unchanged (never a division by a zero weight sum)
        and the event lands in the log for the schema validator."""
        obs.OBS.counter("round/empty")
        obs.OBS.event("dynamics", name="round/empty", round=t)

    def _resample_dropped(self, dropped: np.ndarray,
                          win_np: np.ndarray) -> np.ndarray:
        """Retry-or-replace: each DROPPED winner's slot is refilled by a
        uniform draw among its cluster's currently-available non-winners
        with local data (an empty candidate pool forfeits the slot).
        Draws come from the dedicated host dynamics rng, so replacement
        picks are a pure function of (seed, outcome stream) — identical
        across cohort runtimes."""
        chosen: List[int] = []
        taken = win_np.copy()
        for gid in dropped:
            cand = np.nonzero(
                (self._host_clusters == self._host_clusters[int(gid)])
                & self._host_avail & ~taken & (self._host_sizes > 0))[0]
            if cand.size == 0:
                continue
            pick = int(cand[self._dyn_rng.integers(cand.size)])
            taken[pick] = True
            chosen.append(pick)
        return np.asarray(chosen, np.int64)

    def _maybe_fold_buffer(self, t: int, force: bool = False) -> int:
        """Fold the arrived late updates into the global model when the
        FedBuff boundary hits: goal-count reached, the oldest arrived
        entry timed out, or ``force`` (the final round folds whatever has
        arrived; updates still in flight when the run ends are lost —
        they never reached the server).  Each entry's delta is scaled by
        its staleness discount times its share of the folded data mass,
        so the fold is a staleness-weighted FedAvg over the buffer."""
        arrived = [e for e in self._late_buffer if e.arrival <= t]
        if not arrived:
            return 0
        oldest = min(e.round for e in arrived)
        if not (force or len(arrived) >= self.cfg.buffer_goal
                or t - oldest >= self.cfg.buffer_timeout):
            return 0
        with obs.span("round/buffer_fold", round=t, entries=len(arrived)):
            total = sum(e.mass for e in arrived)
            p = self.params
            for e in arrived:
                c = (DYN.staleness_weight(self.cfg, t - e.round)
                     * e.mass / total)
                p = self._fold_one(p, e.delta, c)
            self.params = p
        self._late_buffer = [e for e in self._late_buffer
                             if e.arrival > t]
        obs.OBS.counter("dyn/buffer_folds")
        obs.OBS.event("dynamics", name="buffer/fold", round=t,
                      entries=len(arrived), oldest=oldest)
        return len(arrived)

    def _dispatch_round_dyn(self, t: int, eval_now: bool,
                            final: bool = False) -> None:
        """The dynamics-aware dispatch: one fused (selection + fault
        model) step, then aggregation over the outcome mask — COMPLETED
        winners plus retry-or-replace substitutes aggregate now (FedAvg
        re-weights over them automatically), LATE winners feed the
        buffered path, DROPPED ones only burned energy.  The extra host
        traffic vs the dynamics-free loop is one batched fetch of the
        outcome codes + next availability mask alongside the winner
        mask."""
        cfg = self.cfg
        with obs.span("round/dispatch", round=t):
            with obs.span("round/select", round=t):
                (new_state, new_dyn, win, outcome,
                 metrics) = self._round_step(self.state, self.dyn_state,
                                             self._next_key(),
                                             self._next_dyn_key())
                win_np, out_np, next_avail = obs.device_get(
                    (win, outcome, new_dyn.avail))
                sel_idx = np.nonzero(win_np)[0]
            completed, late, dropped = DYN.split_outcomes(sel_idx, out_np)
            self.outcome_log.append(out_np[sel_idx])
            repl = (self._resample_dropped(dropped, win_np)
                    if cfg.replace_dropped and dropped.size
                    else np.empty((0,), np.int64))
            train_idx = np.concatenate(
                [completed.astype(np.int64), repl])
            dyn_row: Dict[str, float] = {"num_replaced": int(repl.size)}
            if dropped.size:
                obs.OBS.counter("dyn/dropped", int(dropped.size))
            if late.size:
                obs.OBS.counter("dyn/deadline_miss", int(late.size))
            if repl.size:
                obs.OBS.counter("dyn/replaced", int(repl.size))

            params0 = self.params
            buffered = cfg.aggregation == "buffered"
            if buffered and late.size:
                # the late sub-cohort trains from the same globals it was
                # dispatched with; its aggregate becomes a buffered delta
                with obs.span("round/train_late", round=t,
                              cohort=int(late.size)):
                    late_agg = self.runtime.train_cohort(
                        params0, late, self._host_history)
                if late_agg is not None:
                    self._late_buffer.append(_BufferedUpdate(
                        delta=self._delta_step(late_agg, params0),
                        mass=float(self._host_sizes[late].sum()),
                        round=t, arrival=t + 1))
            with obs.span("round/train", round=t,
                          cohort=int(train_idx.size)):
                new_params = self.runtime.train_cohort(
                    params0, train_idx, self._host_history)
            if new_params is not None:
                self.params = new_params
            else:
                self._log_empty_round(t)

            self.state = new_state
            self.dyn_state = new_dyn
            self._host_avail = np.asarray(next_avail, bool)
            # the shuffle-seed mirror advances for every client whose
            # local pass actually ran this round (survivors, substitutes
            # and — under buffering — the late trainers); the device-side
            # history keeps the control plane's commitment accounting
            trained = (np.concatenate([train_idx, late.astype(np.int64)])
                       if buffered else train_idx)
            self._host_history[trained] += 1
            folded = self._maybe_fold_buffer(t, force=final)
            dyn_row["buffer_len"] = len(self._late_buffer)
            dyn_row["buffer_folded"] = folded
            if eval_now:
                with obs.span("round/eval", round=t):
                    ev = self._eval_step(self.params, self._test_dev)
            else:
                ev = None
            self._pending.append(_PendingRound(
                round=t, selected=sel_idx, metrics=metrics, eval_pair=ev,
                dyn=dyn_row))

    def _flush_pending(self) -> None:
        """Drain the pending buffer with ONE batched device_get and turn
        every entry into a RoundLog (deferring the fetch cannot change
        the values — they were computed by the same programs)."""
        if not self._pending:
            return
        with obs.span("round/drain", rounds=len(self._pending),
                      first=self._pending[0].round):
            fetched = obs.device_get(
                [(p.metrics, p.eval_pair) for p in self._pending])
        for p, (m, ev) in zip(self._pending, fetched):
            acc, loss = ((float(ev[0]), float(ev[1])) if ev is not None
                         else (float("nan"), float("nan")))
            if ev is not None:
                self._last_eval = (acc, loss)
            self.total_client_reward += float(m["client_reward_sum"])
            self.logs.append(RoundLog(
                round=p.round, selected=p.selected, test_acc=acc,
                test_loss=loss, energy_std=float(m["energy_std"]),
                mean_bid=float(m["mean_bid"]),
                server_reward=float(m["server_reward"]),
                client_reward_sum=float(m["client_reward_sum"]),
                vds_gap=float(m["vds_gap"])))
            # per-round series row: every scalar is already a host float
            # from the batched fetch above — recording adds no sync
            extra: Dict[str, float] = {}
            for k in _DYN_METRIC_KEYS:
                if k in m:
                    extra[k] = float(m[k])
            if p.dyn is not None:
                extra.update({k: float(v) for k, v in p.dyn.items()})
            obs.OBS.record_round(
                p.round, test_acc=acc, test_loss=loss,
                energy_std=float(m["energy_std"]),
                mean_bid=float(m["mean_bid"]),
                server_reward=float(m["server_reward"]),
                client_reward_sum=float(m["client_reward_sum"]),
                vds_gap=float(m["vds_gap"]),
                num_selected=int(p.selected.size), **extra)
        self._pending.clear()
        obs.flush()        # the logging boundary: sinks see I/O only here

    def run_round(self, t: int) -> RoundLog:
        """One synchronous FL round (dispatch + immediate flush) — the
        single-round API; the async pipeline lives in :meth:`run`."""
        self._dispatch_round(t, self._eval_due(t))
        self._flush_pending()
        return self.logs[-1]

    # ------------------------------------------------------------------
    def run(self, rounds: Optional[int] = None, verbose: bool = False,
            audit_sync: bool = False, audit_warm_rounds: int = 2):
        """The async round loop.  ``verbose`` prints a progress line
        every 5 rounds showing the *last drained* eval (NaN until one
        drains) — verbosity must never change the measured eval cadence
        (it used to force an eval at every print boundary, so logs and
        params depended on the flag; regression-tested in
        tests/test_obs.py).  ``audit_sync`` wraps every dispatch from
        round ``audit_warm_rounds`` on in the transfer-guard sync
        auditor: an implicit host transfer inside the warm loop raises
        at the offending op (obs.sync_audit)."""
        with obs.span("run/cluster", scheme=self.cfg.scheme):
            self.cluster()
        warmup = getattr(self.runtime, "warmup", None)
        if warmup is not None:    # device runtime: compile every class
            with obs.span("run/warmup"):
                warmup(self.params)
        T = rounds if rounds is not None else self.cfg.rounds
        for t in range(T):
            printing = verbose and (t % 5 == 0 or t == T - 1)
            final = t == T - 1
            if audit_sync and t >= audit_warm_rounds:
                with obs.sync_audit():
                    self._dispatch_round(t, self._eval_due(t, final=final),
                                         final=final)
            else:
                self._dispatch_round(t, self._eval_due(t, final=final),
                                     final=final)
            if printing:
                self._flush_pending()
                log = self.logs[-1]
                acc, loss = self._last_eval
                obs.log(f"  round {t:3d} acc={acc:.3f} "
                        f"loss={loss:.3f} "
                        f"E_std={log.energy_std:.3f} "
                        f"bid={log.mean_bid:.3f} "
                        f"vds_gap={log.vds_gap:.3f}")
        self._flush_pending()
        return self.logs
