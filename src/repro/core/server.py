"""Federated server: the full Algorithm 1 loop.

Stage 1  (once)    : gradient/weight clustering of all clients.
Stage 2  (per round): cost -> Nash bids -> s_min threshold -> per-cluster
                      winners (or the paper's baselines' random picks),
                      rewards, energy/history update and round metrics —
                      fused into ONE jitted program per round
                      (repro.core.rounds), one host transfer for logging.
Stage 3  (per round): winners run I local epochs (FedAvg local SGD, or
                      FedProx with the proximal term), server aggregates
                      w_{t+1} = sum_k p_k w^k_{t+1}, energy/history update.

Stage-3 execution is delegated to a pluggable :mod:`repro.sim` cohort
runtime (``cfg.runtime``): ``sequential`` runs clients one by one (the
paper's own execution model, kept as the reference oracle), ``vectorized``
runs the whole cohort as one compiled vmap/scan program per size bucket,
``sharded`` maps it over the cohort mesh, and ``device`` keeps the whole
fleet resident on device (repro.sim.fleet) so per-round assembly is an
on-device gather; the *launch* layer additionally maps cohorts onto mesh
axes for the TPU-scale path — see repro/launch/train.py.

The round loop is ASYNC: each round's control-plane metrics (and its
eval scalars, computed every ``cfg.eval_every`` rounds by one fused
jitted accuracy+loss program) stay on device in a pending buffer, and
round t+1's selection/training dispatch while round t's fetches are
still in flight.  One batched ``device_get`` drains the buffer at
logging boundaries (verbose prints, ``run_round`` returns, end of run) —
the only unconditional per-round host transfer left is the winner mask,
which stage-3's host-seeded shuffle rng genuinely needs.

With fleet dynamics on (``cfg.dynamics_enabled`` — any churn or a
positive deadline) the fused round step additionally runs the
repro.sim.dynamics fault model, and the aggregation path degrades
gracefully instead of assuming a full cohort: only COMPLETED winners
(plus retry-or-replace substitutes for DROPPED ones) aggregate
synchronously — FedAvg re-weights over the survivors automatically
because the cohort runtimes normalize within whatever index set they
are handed; a zero-survivor round leaves the params untouched and logs
a ``round/empty`` dynamics event (never a 0/0).  Under ``--aggregation
buffered`` LATE winners still train, but their update lands in a
device-resident buffer as a staleness-stamped delta and folds into the
global model FedBuff-style at goal-count or timeout boundaries
(``round/buffer_fold`` spans).  With dynamics off both aggregation
modes take the exact pre-dynamics code path — the synchronous oracle —
so churn-0 runs stay bit-identical (tests/test_dynamics.py).
"""
from __future__ import annotations

import json
import os
from collections import deque
from copy import deepcopy
from dataclasses import dataclass, replace as dc_replace
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import FLConfig
from repro.core import aggregation as AGG
from repro.core import clustering as CL
from repro.core import energy as EN
from repro.core import rounds as RND
from repro.core import schemes as SCH
from repro.core import selection as SEL
from repro.core.adapters import ModelAdapter
from repro.optim import apply_updates, sgd
from repro.sim import dynamics as DYN
from repro.sim.runtime import make_runtime


@dataclass
class RoundLog:
    round: int
    selected: np.ndarray
    test_acc: float            # NaN on rounds skipped by cfg.eval_every
    test_loss: float
    energy_std: float
    mean_bid: float
    server_reward: float
    client_reward_sum: float
    vds_gap: float
    # True when the round was off the eval cadence (test_acc NaN by
    # design); a NaN with eval_skipped=False means the eval RAN and the
    # model diverged — the two cases were indistinguishable before
    eval_skipped: bool = False


@dataclass
class _PendingRound:
    """A dispatched round whose host fetches haven't happened yet:
    ``metrics`` is the round step's on-device scalar dict, ``eval_pair``
    the fused (accuracy, loss) device scalars or None off-cadence,
    ``dyn`` the host-side dynamics scalars (replacements, buffer depth)
    or None with dynamics off."""

    round: int
    selected: np.ndarray
    metrics: Any
    eval_pair: Optional[Any]
    dyn: Optional[Dict[str, float]] = None
    # screened-aggregation reports (device scalar dicts) for every
    # defended sub-cohort this round dispatched (late first, main last);
    # they drain with the same batched fetch as the metrics
    defense: Optional[List[Any]] = None


@dataclass
class _BufferedUpdate:
    """One late update parked in the device-resident FedBuff buffer:
    ``delta`` is the late sub-cohort's aggregated param delta (vs the
    globals it trained from) as a device tree, ``mass`` its data mass
    (sum of local sizes — the FedAvg numerator it would have carried),
    ``round`` the dispatch round and ``arrival`` the first round the
    server can fold it (dispatch + 1: late means after the deadline)."""

    delta: Any
    mass: float
    round: int
    arrival: int
    # fraction of the sub-cohort's rows that survived screening (device
    # scalar from the screened report), or None undefended.  The fold
    # scales the entry's mass by it so a fully-quarantined late cohort
    # contributes ZERO mass — its (zeroed) delta must not dilute the
    # fold, and an all-quarantined buffer must not divide by zero.
    mass_scale: Any = None


@dataclass
class _RingEntry:
    """One watchdog ring snapshot: ``tree`` is exactly the
    :meth:`FederatedServer._ckpt_tree` pytree (params, selection state,
    key chain, defense state, server LR — the PR 8 checkpoint format,
    held on device instead of disk; JAX arrays are immutable so the refs
    ARE the snapshot), plus the host-side state a rollback must restore
    verbatim."""

    round: int
    tree: Dict[str, Any]
    reward: float
    last_eval: Tuple[float, float]
    dyn_rng_state: Optional[dict] = None
    host_avail: Optional[np.ndarray] = None


# device metric keys the dynamics round step adds; drained with the same
# batched fetch as the base metrics and mirrored into the round series
_DYN_METRIC_KEYS = ("num_completed", "num_late", "num_dropped",
                    "staleness_mean", "staleness_max", "mean_latency",
                    "num_avail")

# device metric keys the defended round step adds (repro.core.rounds
# emits them only when SelectionState carries strikes; trust_* is the
# continuous reputation score the pricing mode bids against)
_DEF_METRIC_KEYS = ("num_banned", "trust_mean", "trust_min")

# device metric keys the selection-scheme zoo adds: fairness_hist_std
# comes from every scheme; the budget_* ledger scalars only from
# scheme_state-bearing schemes (longterm_auction), the latency ones only
# from fedcs — all drained with the same batched fetch
_SCHEME_METRIC_KEYS = ("fairness_hist_std", "budget_spent",
                       "budget_remaining", "budget_queue",
                       "pred_latency_mean", "num_feasible")


class FederatedServer:
    def __init__(self, cfg: FLConfig, adapter: ModelAdapter,
                 x: np.ndarray, y: np.ndarray, clients,
                 test_batch: Dict[str, np.ndarray],
                 assign_fn=None, seed: Optional[int] = None):
        self.cfg = cfg
        self.adapter = adapter
        self.x, self.y = x, y
        self.clients = clients
        self.test_batch = test_batch
        self.assign_fn = assign_fn
        self.key = jax.random.PRNGKey(cfg.seed if seed is None else seed)
        self.params = adapter.init(self._next_key())
        self.logs: List[RoundLog] = []
        self.runtime = make_runtime(cfg, adapter, x, y, clients)

        sizes = jnp.asarray([c.size for c in clients], jnp.int32)
        self.dynamics = cfg.dynamics_enabled
        self.defended = cfg.defended
        self.state = SEL.SelectionState(
            clusters=jnp.zeros((cfg.num_clients,), jnp.int32),
            residual=EN.init_energy(cfg, self._next_key()),
            history=jnp.zeros((cfg.num_clients,), jnp.int32),
            local_sizes=sizes,
            # None with dynamics off: the field must not exist as an
            # array leaf or the dynamics-free round traces would change
            staleness=(jnp.zeros((cfg.num_clients,), jnp.int32)
                       if self.dynamics else None),
            # same rule for the reputation ledger with defenses off
            strikes=(jnp.zeros((cfg.num_clients,), jnp.float32)
                     if self.defended else None),
            # per-scheme carried state (None for stateless schemes —
            # same Optional-last-field rule as staleness/strikes)
            scheme_state=SCH.init_scheme_state(cfg),
        )
        from repro.core.virtual_dataset import client_count_histograms
        from repro.data.partition import global_histogram
        self.global_hist = global_histogram(y, cfg.num_classes)
        self.client_labels = [y[c.train_idx] for c in clients]
        self.total_client_reward = 0.0
        # fused round control plane: one jitted (state, key) -> (state,
        # win, metrics) program; metrics (energy std, mean winning bid,
        # reward sums, vds-gap) are computed on device so run_round does
        # one host transfer for the whole control plane.
        self._round_step = RND.make_round_step(
            cfg, client_count_histograms(self.client_labels,
                                         cfg.num_classes),
            self.global_hist, dynamics=self.dynamics)
        if self.dynamics:
            # the DEDICATED dynamics chain: split off its own root so
            # churn-0 runs consume the selection chain identically
            self._dyn_key = DYN.dynamics_key(cfg)
            self.dyn_state = DYN.init_dynamics(cfg)
            # host mirrors the replacement sampler reads: round-start
            # availability and (after stage 1) cluster ids
            self._host_avail = np.ones((cfg.num_clients,), bool)
            self._host_clusters = np.zeros((cfg.num_clients,), np.int64)
            self._host_sizes = np.asarray([c.size for c in clients],
                                          np.int64)
            # replacement draws come from their own host rng chain, so
            # they are a pure function of (seed, outcome stream) and
            # identical across cohort runtimes
            self._dyn_rng = np.random.default_rng(
                np.uint32(cfg.seed) + 0x5D7A)
            # scheme-aware replacement constraint (fedcs: substitutes
            # must themselves be plausibly deadline-feasible); None from
            # the registry means unconstrained
            m = SCH.host_replacement_mask(cfg, self._host_sizes)
            self._host_feasible = (np.ones((cfg.num_clients,), bool)
                                   if m is None else np.asarray(m, bool))
            self.outcome_log: List[np.ndarray] = []   # per-round winner codes
            self._late_buffer: List[_BufferedUpdate] = []
            self._delta_step = jax.jit(
                lambda new, old: jax.tree.map(jnp.subtract, new, old))
            self._fold_one = jax.jit(
                lambda p, d, c: jax.tree.map(lambda a, b: a + c * b, p, d))
        if self.defended:
            # Byzantine-tolerant stage 3 (repro.core.aggregation): the
            # adversary chain + population mask are frozen at init (both
            # pure functions of cfg — identical across runtimes and
            # resumes); one screened program handles every cohort size by
            # padding rows up to the static capacity
            self._adv_root = DYN.adversary_key(cfg)
            self._adv_mask = np.asarray(
                obs.device_get(DYN.adversary_mask(cfg)), bool)
            self._screen_cap = AGG.screen_capacity(cfg)
            self._screen_step = AGG.make_screened_step(cfg)
            self._apply_delta = AGG.make_apply_delta(self.params)
            # jitted so the warm loop never runs eager index/key ops —
            # those materialize scalar constants via implicit h2d
            # transfers, which the sync auditor rejects
            self._gather_rows = jax.jit(
                lambda d, i: jnp.take(d, i, axis=0, mode="clip"))
            self._fold_key = jax.jit(jax.random.fold_in)
            # running defense statistics (clip EMA + adaptive MAD band /
            # pressure when --defense-mode adaptive, tighten factor when
            # the watchdog is on); stays on device between rounds
            self._defense_state = AGG.init_defense_state(cfg)
            # host tallies filled at flush boundaries (launch summary)
            self.defense_totals: Dict[str, int] = {"quarantined": 0,
                                                   "screened": 0,
                                                   "banned_final": 0}
        self._watchdog = cfg.watchdog_enabled
        if self._watchdog:
            # divergence watchdog: ring of the last K healthy snapshots
            # (each a _ckpt_tree pytree — the checkpoint format, held on
            # device), a detector over the drained eval stream, and a
            # rollback policy that restores the newest healthy entry,
            # tightens the defense and decays the server LR
            self._wd_ring: deque = deque(
                maxlen=max(int(cfg.watchdog_ring), 1))
            self._srv_lr = jnp.float32(1.0)
            self._wd_loss_ema: Optional[float] = None
            self._wd_acc_peak = float("-inf")
            self._wd_healthy = False      # healthy eval since last rollback
            self._wd_rollbacks = 0
            self.watchdog_totals: Dict[str, int] = {"rollbacks": 0,
                                                    "snapshots": 0}
            # server LR enters as a bit-exact no-op at lr=1.0: the delta
            # path scales by exactly 1.0 (IEEE identity) and the blend
            # `b + (s-1)*(b-a)` adds exactly 0.0 — a watchdog-on run
            # that never rolls back matches watchdog-off numerically
            self._scale_delta = jax.jit(lambda a, s: a * s)
            self._wd_blend = jax.jit(
                lambda p0, p1, s: jax.tree.map(
                    lambda a, b: b + (s - 1.0) * (b - a), p0, p1))
        # host mirror of participation counts: stage-3 shuffle seeding
        # reads history per winner, which on the device array cost one
        # int(history[i]) sync per client per round.
        self._host_history = np.zeros((cfg.num_clients,), np.int64)
        # fused eval: accuracy + loss as ONE jitted program (the two
        # nested jits inline), so an eval round costs one deferred fetch
        # instead of two blocking ones; the test batch is committed to
        # device once instead of being re-transferred per round.
        def _eval(p, b):
            obs.jax_stats.note_trace("eval")     # trace-time side effect
            return adapter.accuracy(p, b), adapter.loss(p, b)

        self._eval_step = jax.jit(_eval)
        self._test_dev = obs.device_put(test_batch)
        self._pending: List[_PendingRound] = []
        # last eval pair actually drained (progress prints show this
        # instead of forcing an off-cadence eval — see run())
        self._last_eval = (float("nan"), float("nan"))

    # ------------------------------------------------------------------
    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def _next_dyn_key(self):
        self._dyn_key, k = jax.random.split(self._dyn_key)
        return k

    # ------------------------------------------------------------------
    def cluster(self):
        """Stage 1: cluster clients (scheme-dependent feature).

        With the default ``assign_fn=None`` k-means routes through the
        fused clustering engine (repro.core.clustering.kmeans): one jit
        for seeding + Lloyd + restart-argmin, the Pallas assign+update
        kernel on TPU and its jnp twin elsewhere; ``assign_fn`` overrides
        assignment only (testing hook)."""
        cfg = self.cfg
        if cfg.scheme == "random":
            return
        feature_kind = ("weights" if cfg.scheme == "weights_cluster_random"
                        else "gradient")
        data = [(self.x[c.train_idx], self.y[c.train_idx])
                for c in self.clients]

        def local_steps_fn(params, x, y, key):
            # Wang et al. [2] feature: local model delta after 1 epoch SGD
            init, upd = sgd(cfg.lr)
            opt = init(params)
            p = params
            bs = min(32, x.shape[0])
            for i in range(0, x.shape[0] - bs + 1, bs):
                b = {"x": x[i:i + bs], "y": y[i:i + bs]}
                g = self.adapter.grad(p, b)
                u, opt = upd(g, opt, p)
                p = apply_updates(p, u)
            delta = jax.tree.map(lambda a, b: (a - b).reshape(-1), p, params)
            return jnp.concatenate(jax.tree.leaves(delta))

        key = self._next_key()
        # the runtime may compute the whole feature pass as one batched
        # program (vectorized backend); None -> reference per-client loop
        feats = self.runtime.cluster_features(self.params, key, feature_kind)
        labels, cent, feats = CL.cluster_clients(
            self.adapter.grad, self.params, data, cfg, key,
            feature_kind=feature_kind, local_steps_fn=local_steps_fn,
            assign_fn=self.assign_fn, precomputed_feats=feats)
        self.state = SEL.SelectionState(
            clusters=labels.astype(jnp.int32), residual=self.state.residual,
            history=self.state.history, local_sizes=self.state.local_sizes,
            staleness=self.state.staleness, strikes=self.state.strikes,
            scheme_state=self.state.scheme_state)
        if self.dynamics:
            self._host_clusters = np.asarray(obs.device_get(labels),
                                             np.int64)

    # ------------------------------------------------------------------
    def local_train(self, client_idx: int, global_params):
        return self.runtime.train_client(
            global_params, client_idx, int(self._host_history[client_idx]))

    # -- defended aggregation ------------------------------------------
    def _train_defended(self, params0, train_idx: np.ndarray, t: int,
                        chan: int, strikes):
        """Defended stage 3: the runtime returns the cohort's per-client
        flat deltas, the fused screened program (repro.core.aggregation)
        corrupts (adversary model), quarantines, defends, aggregates and
        updates the reputation ledger in one call, and the screened
        aggregate delta is applied to ``params0``.  ``chan`` separates
        the per-round adversary key of the main (0) and buffered-late
        (1) sub-cohorts so their corruption draws never collide.
        Returns ``(new_params, report, new_strikes)`` — all None for an
        empty cohort (strikes pass through unchanged)."""
        upd = self.runtime.train_cohort_updates(params0, train_idx,
                                                self._host_history)
        if upd is None:
            return None, None, strikes
        ids = np.asarray(upd.client_idx, np.int32)
        real = np.flatnonzero(ids >= 0).astype(np.int32)
        if real.size == 0:
            return None, None, strikes
        cap = self._screen_cap
        while cap < real.size:     # never hit: capacity bounds the cohort
            cap *= 2
        # compact the runtimes' padding rows out and pad to the one
        # static capacity in a single on-device gather driven by a
        # host-built index plan (padding slots gather row 0 and are
        # masked by valid=False), so the screened program compiles
        # exactly once and the warm loop's only h2d traffic is these
        # explicit, counted plan arrays — no eager fill constants, which
        # the sync auditor (correctly) rejects as implicit transfers
        gidx = np.zeros((cap,), np.int32)
        gidx[:real.size] = real
        w = np.zeros((cap,), np.float32)
        w[:real.size] = np.asarray(upd.weights, np.float32)[real]
        idp = np.full((cap,), -1, np.int32)
        idp[:real.size] = ids[real]
        valid = idp >= 0
        adv = valid & self._adv_mask[np.clip(idp, 0, None)]
        gd, wd, vd, ad, idd, rnd, fold = obs.device_put(
            (gidx, w, valid, adv, idp, np.int32(t),
             np.uint32(2 * t + chan + 1)))
        dpad = self._gather_rows(upd.deltas, gd)
        key = self._fold_key(self._adv_root, fold)
        agg, new_strikes, self._defense_state, report = self._screen_step(
            dpad, wd, vd, ad, idd, strikes, self._defense_state, rnd, key)
        if self._watchdog:
            # server LR (decayed by rollbacks): exact no-op at 1.0
            agg = self._scale_delta(agg, self._srv_lr)
        return self._apply_delta(params0, agg), report, new_strikes

    # ------------------------------------------------------------------
    def _eval_due(self, t: int, final: bool = False) -> bool:
        return final or self.cfg.eval_every <= 1 \
            or t % self.cfg.eval_every == 0

    def _dispatch_round(self, t: int, eval_now: bool,
                        final: bool = False) -> None:
        """Dispatch one FL round without fetching its results.  The whole
        stage-2 control plane (selection, rewards, energy/history update,
        round metrics) is one jitted call (repro.core.rounds
        .make_round_step); only the winner mask is fetched — stage-3's
        host-seeded shuffle rng needs it — while the metric scalars (and
        the fused eval pair, when due) stay on device in the pending
        buffer until the next logging boundary.  With fleet dynamics on
        the fused step also runs the fault model and dispatch degrades
        gracefully over the outcome mask (:meth:`_dispatch_round_dyn`)."""
        if self.dynamics:
            return self._dispatch_round_dyn(t, eval_now, final)
        with obs.span("round/dispatch", round=t):
            with obs.span("round/select", round=t):
                new_state, win, metrics = self._round_step(self.state,
                                                           self._next_key())
                # the one unconditional per-round fetch (explicit, counted)
                win_np = obs.device_get(win)
                sel_idx = np.nonzero(win_np)[0]

            # stage 3: local training + aggregation (cohort runtime
            # backend); shuffle seeds read the pre-round host history
            # mirror
            defense: Optional[List[Any]] = None
            with obs.span("round/train", round=t,
                          cohort=int(sel_idx.size)):
                if self.defended:
                    new_params, rep, strikes = self._train_defended(
                        self.params, sel_idx, t, 0, new_state.strikes)
                    new_state = dc_replace(new_state, strikes=strikes)
                    if rep is not None:
                        defense = [rep]
                else:
                    new_params = self.runtime.train_cohort(
                        self.params, sel_idx, self._host_history)
                    if new_params is not None and self._watchdog:
                        new_params = self._wd_blend(self.params, new_params,
                                                    self._srv_lr)
            if new_params is not None:
                self.params = new_params
            else:
                # zero-winner (or all-zero-size) round: the runtimes
                # return None instead of a 0/0 aggregate — params pass
                # through unchanged and the event is visible in the log
                self._log_empty_round(t)

            self.state = new_state
            self._host_history[sel_idx] += 1
            if eval_now:
                with obs.span("round/eval", round=t):
                    ev = self._eval_step(self.params, self._test_dev)
            else:
                ev = None
            self._pending.append(_PendingRound(
                round=t, selected=sel_idx, metrics=metrics, eval_pair=ev,
                defense=defense))

    # -- fleet dynamics ------------------------------------------------
    def _log_empty_round(self, t: int) -> None:
        """A round whose synchronous aggregate had no survivors: params
        pass through unchanged (never a division by a zero weight sum)
        and the event lands in the log for the schema validator."""
        obs.OBS.counter("round/empty")
        obs.OBS.event("dynamics", name="round/empty", round=t)

    def _resample_dropped(self, dropped: np.ndarray,
                          win_np: np.ndarray) -> np.ndarray:
        """Retry-or-replace: each DROPPED winner's slot is refilled by a
        uniform draw among its cluster's currently-available non-winners
        with local data (an empty candidate pool forfeits the slot).
        Draws come from the dedicated host dynamics rng, so replacement
        picks are a pure function of (seed, outcome stream) — identical
        across cohort runtimes.  Under ``--scheme-select fedcs`` the
        candidate pool is further restricted to plausibly
        deadline-feasible clients (schemes.host_replacement_mask) — a
        substitute that can't meet the deadline would just convert the
        DROPPED slot into a LATE one."""
        chosen: List[int] = []
        taken = win_np.copy()
        for gid in dropped:
            cand = np.nonzero(
                (self._host_clusters == self._host_clusters[int(gid)])
                & self._host_avail & ~taken & (self._host_sizes > 0)
                & self._host_feasible)[0]
            if cand.size == 0:
                continue
            pick = int(cand[self._dyn_rng.integers(cand.size)])
            taken[pick] = True
            chosen.append(pick)
        return np.asarray(chosen, np.int64)

    def _maybe_fold_buffer(self, t: int, force: bool = False) -> int:
        """Fold the arrived late updates into the global model when the
        FedBuff boundary hits: goal-count reached, the oldest arrived
        entry timed out, or ``force`` (the final round folds whatever has
        arrived; updates still in flight when the run ends are lost —
        they never reached the server).  Each entry's delta is scaled by
        its staleness discount times its share of the folded data mass,
        so the fold is a staleness-weighted FedAvg over the buffer."""
        arrived = [e for e in self._late_buffer if e.arrival <= t]
        if not arrived:
            return 0
        oldest = min(e.round for e in arrived)
        if not (force or len(arrived) >= self.cfg.buffer_goal
                or t - oldest >= self.cfg.buffer_timeout):
            return 0
        # defended entries carry their screening survivor fraction as a
        # device scalar; one explicit (counted) fetch scales the masses
        # so quarantined rows carry no weight in the fold
        if any(e.mass_scale is not None for e in arrived):
            scales = obs.device_get(
                [e.mass_scale if e.mass_scale is not None
                 else np.float32(1.0) for e in arrived])
            masses = [e.mass * float(s) for e, s in zip(arrived, scales)]
        else:
            masses = [e.mass for e in arrived]
        total = sum(masses)
        if total <= 0.0:
            # every arrived row was quarantined: the buffered deltas are
            # all screened-to-zero — drop them loudly instead of folding
            # a 0/0 into the params
            self._late_buffer = [e for e in self._late_buffer
                                 if e.arrival > t]
            obs.OBS.counter("dyn/buffer_all_quarantined")
            obs.OBS.event("dynamics", name="buffer/all_quarantined",
                          round=t, entries=len(arrived))
            return 0
        with obs.span("round/buffer_fold", round=t, entries=len(arrived)):
            p = self.params
            for e, mass in zip(arrived, masses):
                c = (DYN.staleness_weight(self.cfg, t - e.round)
                     * mass / total)
                p = self._fold_one(p, e.delta, c)
            self.params = p
        self._late_buffer = [e for e in self._late_buffer
                             if e.arrival > t]
        obs.OBS.counter("dyn/buffer_folds")
        obs.OBS.event("dynamics", name="buffer/fold", round=t,
                      entries=len(arrived), oldest=oldest)
        return len(arrived)

    def _dispatch_round_dyn(self, t: int, eval_now: bool,
                            final: bool = False) -> None:
        """The dynamics-aware dispatch: one fused (selection + fault
        model) step, then aggregation over the outcome mask — COMPLETED
        winners plus retry-or-replace substitutes aggregate now (FedAvg
        re-weights over them automatically), LATE winners feed the
        buffered path, DROPPED ones only burned energy.  The extra host
        traffic vs the dynamics-free loop is one batched fetch of the
        outcome codes + next availability mask alongside the winner
        mask."""
        cfg = self.cfg
        with obs.span("round/dispatch", round=t):
            with obs.span("round/select", round=t):
                (new_state, new_dyn, win, outcome,
                 metrics) = self._round_step(self.state, self.dyn_state,
                                             self._next_key(),
                                             self._next_dyn_key())
                win_np, out_np, next_avail = obs.device_get(
                    (win, outcome, new_dyn.avail))
                sel_idx = np.nonzero(win_np)[0]
            completed, late, dropped = DYN.split_outcomes(sel_idx, out_np)
            self.outcome_log.append(out_np[sel_idx])
            repl = (self._resample_dropped(dropped, win_np)
                    if cfg.replace_dropped and dropped.size
                    else np.empty((0,), np.int64))
            train_idx = np.concatenate(
                [completed.astype(np.int64), repl])
            dyn_row: Dict[str, float] = {"num_replaced": int(repl.size)}
            if dropped.size:
                obs.OBS.counter("dyn/dropped", int(dropped.size))
            if late.size:
                obs.OBS.counter("dyn/deadline_miss", int(late.size))
            if repl.size:
                obs.OBS.counter("dyn/replaced", int(repl.size))

            params0 = self.params
            buffered = cfg.aggregation == "buffered"
            defense: List[Any] = []
            if buffered and late.size:
                # the late sub-cohort trains from the same globals it was
                # dispatched with; its aggregate becomes a buffered delta
                with obs.span("round/train_late", round=t,
                              cohort=int(late.size)):
                    if self.defended:
                        late_agg, rep, strikes = self._train_defended(
                            params0, late, t, 1, new_state.strikes)
                        new_state = dc_replace(new_state, strikes=strikes)
                        if rep is not None:
                            defense.append(rep)
                    else:
                        late_agg = self.runtime.train_cohort(
                            params0, late, self._host_history)
                if late_agg is not None:
                    self._late_buffer.append(_BufferedUpdate(
                        delta=self._delta_step(late_agg, params0),
                        mass=float(self._host_sizes[late].sum()),
                        round=t, arrival=t + 1,
                        # survivor fraction rides as a device scalar and
                        # is fetched at fold time: a fully-quarantined
                        # late cohort must fold with zero mass
                        mass_scale=(rep["survivor_frac"]
                                    if self.defended and rep is not None
                                    else None)))
            with obs.span("round/train", round=t,
                          cohort=int(train_idx.size)):
                if self.defended:
                    new_params, rep, strikes = self._train_defended(
                        params0, train_idx, t, 0, new_state.strikes)
                    new_state = dc_replace(new_state, strikes=strikes)
                    if rep is not None:
                        defense.append(rep)
                else:
                    new_params = self.runtime.train_cohort(
                        params0, train_idx, self._host_history)
                    if new_params is not None and self._watchdog:
                        new_params = self._wd_blend(params0, new_params,
                                                    self._srv_lr)
            if new_params is not None:
                self.params = new_params
            else:
                self._log_empty_round(t)

            self.state = new_state
            self.dyn_state = new_dyn
            self._host_avail = np.asarray(next_avail, bool)
            # the shuffle-seed mirror advances for every client whose
            # local pass actually ran this round (survivors, substitutes
            # and — under buffering — the late trainers); the device-side
            # history keeps the control plane's commitment accounting
            trained = (np.concatenate([train_idx, late.astype(np.int64)])
                       if buffered else train_idx)
            self._host_history[trained] += 1
            folded = self._maybe_fold_buffer(t, force=final)
            dyn_row["buffer_len"] = len(self._late_buffer)
            dyn_row["buffer_folded"] = folded
            if eval_now:
                with obs.span("round/eval", round=t):
                    ev = self._eval_step(self.params, self._test_dev)
            else:
                ev = None
            self._pending.append(_PendingRound(
                round=t, selected=sel_idx, metrics=metrics, eval_pair=ev,
                dyn=dyn_row, defense=defense or None))

    def _flush_pending(self) -> None:
        """Drain the pending buffer with ONE batched device_get and turn
        every entry into a RoundLog (deferring the fetch cannot change
        the values — they were computed by the same programs)."""
        if not self._pending:
            return
        with obs.span("round/drain", rounds=len(self._pending),
                      first=self._pending[0].round):
            fetched = obs.device_get(
                [(p.metrics, p.eval_pair, p.defense)
                 for p in self._pending])
        # watchdog: first divergence trigger across the drained evals (at
        # most ONE rollback per flush — later evals in the same drain ran
        # against the already-poisoned params)
        wd_trigger: Optional[Tuple[str, int]] = None
        wd_healthy_seen = False
        for p, (m, ev, defs) in zip(self._pending, fetched):
            skipped = ev is None
            acc, loss = ((float(ev[0]), float(ev[1])) if not skipped
                         else (float("nan"), float("nan")))
            if not skipped:
                self._last_eval = (acc, loss)
                if not (np.isfinite(acc) and np.isfinite(loss)):
                    # the eval RAN and came back non-finite: the model
                    # diverged (e.g. an unscreened NaN update) — distinct
                    # from an off-cadence skip, and loud in the log
                    obs.OBS.counter("round/diverged")
                    obs.OBS.event("defense", name="round/diverged",
                                  round=p.round)
                if self._watchdog and wd_trigger is None:
                    reason = self._wd_detect(acc, loss)
                    if reason is not None:
                        wd_trigger = (reason, p.round)
                    else:
                        wd_healthy_seen = True
                        self._wd_healthy = True
            self.total_client_reward += float(m["client_reward_sum"])
            self.logs.append(RoundLog(
                round=p.round, selected=p.selected, test_acc=acc,
                test_loss=loss, energy_std=float(m["energy_std"]),
                mean_bid=float(m["mean_bid"]),
                server_reward=float(m["server_reward"]),
                client_reward_sum=float(m["client_reward_sum"]),
                vds_gap=float(m["vds_gap"]), eval_skipped=skipped))
            # per-round series row: every scalar is already a host float
            # from the batched fetch above — recording adds no sync
            extra: Dict[str, float] = {}
            for k in (_DYN_METRIC_KEYS + _DEF_METRIC_KEYS
                      + _SCHEME_METRIC_KEYS):
                if k in m:
                    extra[k] = float(m[k])
            if p.dyn is not None:
                extra.update({k: float(v) for k, v in p.dyn.items()})
            if "num_banned" in extra:
                self.defense_totals["banned_final"] = int(
                    extra["num_banned"])
            if defs:
                nq = sum(float(d["num_quarantined"]) for d in defs)
                ns = sum(float(d["num_screened"]) for d in defs)
                self.defense_totals["quarantined"] += int(nq)
                self.defense_totals["screened"] += int(ns)
                main = defs[-1]     # the synchronous cohort's report
                extra.update(
                    num_quarantined=nq,
                    num_screened=ns,
                    num_survivors=float(main["num_survivors"]),
                    survivor_frac=float(main["survivor_frac"]),
                    clipped_frac=float(main["clipped_frac"]),
                    update_norm_p50=float(main["update_norm_p50"]),
                    update_norm_p99=float(main["update_norm_p99"]),
                    defense_pressure=float(main["defense_pressure"]))
                if nq > 0:
                    obs.OBS.counter("defense/quarantined", int(nq))
                    obs.OBS.event("defense", name="quarantine",
                                  round=p.round, quarantined=int(nq))
                if ns > 0:
                    obs.OBS.counter("defense/screened", int(ns))
                    obs.OBS.event("defense", name="band_screen",
                                  round=p.round, screened=int(ns))
            obs.OBS.record_round(
                p.round, test_acc=acc, test_loss=loss,
                energy_std=float(m["energy_std"]),
                mean_bid=float(m["mean_bid"]),
                server_reward=float(m["server_reward"]),
                client_reward_sum=float(m["client_reward_sum"]),
                vds_gap=float(m["vds_gap"]),
                num_selected=int(p.selected.size),
                eval_skipped=skipped, **extra)
        self._pending.clear()
        if self._watchdog:
            if wd_trigger is not None:
                self._wd_rollback(*wd_trigger)
            elif wd_healthy_seen:
                # the newest drained eval vouches for the CURRENT params:
                # snapshot at this healthy boundary
                self._wd_snapshot(self.logs[-1].round if self.logs else 0)
        obs.flush()        # the logging boundary: sinks see I/O only here

    # -- divergence watchdog -------------------------------------------
    def _wd_detect(self, acc: float, loss: float) -> Optional[str]:
        """Classify one drained eval: None = healthy (detector state
        advances), else the divergence reason.  Loss is judged against a
        slow EMA (spike = watchdog_loss_mult x EMA, with a +0.1 absolute
        slack so near-zero losses don't trip on noise), accuracy against
        its running peak."""
        cfg = self.cfg
        if not (np.isfinite(acc) and np.isfinite(loss)):
            return "non_finite_eval"
        if (self._wd_loss_ema is not None
                and loss > cfg.watchdog_loss_mult * self._wd_loss_ema + 0.1):
            return "loss_spike"
        if acc < self._wd_acc_peak - cfg.watchdog_acc_drop:
            return "acc_collapse"
        self._wd_loss_ema = (loss if self._wd_loss_ema is None
                             else 0.5 * self._wd_loss_ema + 0.5 * loss)
        self._wd_acc_peak = max(self._wd_acc_peak, acc)
        return None

    def _wd_snapshot(self, t: int) -> None:
        """Push the current server state onto the checkpoint ring: the
        tree refs are immutable device arrays, so this is O(host mirrors)
        — no device round-trip, no disk."""
        self._wd_ring.append(_RingEntry(
            round=t, tree=self._ckpt_tree(),
            reward=self.total_client_reward,
            last_eval=self._last_eval,
            dyn_rng_state=(deepcopy(self._dyn_rng.bit_generator.state)
                           if self.dynamics else None),
            host_avail=(self._host_avail.copy() if self.dynamics
                        else None)))
        self.watchdog_totals["snapshots"] += 1

    def _wd_rollback(self, reason: str, bad_round: int) -> None:
        """Restore the newest healthy ring entry, tighten the defense,
        decay the server LR and perturb the key chain so the retried
        rounds explore a different stochastic path.  If the previous
        rollback never produced a healthy eval, the newest entry itself
        is suspect (snapshotted ahead of its validating eval) — it is
        discarded and the next-older entry restores instead."""
        cfg = self.cfg
        if not self._wd_ring:
            return
        if not self._wd_healthy and len(self._wd_ring) > 1:
            self._wd_ring.pop()
        e = self._wd_ring[-1]
        tree = e.tree
        self._wd_rollbacks += 1
        self.params = tree["params"]
        self.state = tree["state"]
        # perturbed key chain: replaying the exact keys would walk the
        # exact same path back into the divergence
        self.key = jax.random.fold_in(
            tree["key"], np.uint32(0x5AFE + self._wd_rollbacks))
        self._host_history = np.asarray(tree["host_history"],
                                        np.int64).copy()
        if self.dynamics:
            self.dyn_state = DYN.DynamicsState(avail=tree["dyn_avail"])
            self._dyn_key = tree["dyn_key"]
            self._host_avail = e.host_avail.copy()
            self._dyn_rng.bit_generator.state = deepcopy(e.dyn_rng_state)
            # in-flight late updates were trained from abandoned params
            self._late_buffer = []
        if self.defended:
            # escalate from the CURRENT tighten, not the snapshot's: a
            # second rollback onto the same restore point retries with a
            # tighter band than the first, not an identical one
            ds = tree["defense_state"]
            if ds.tighten is not None:
                ds = dc_replace(ds, tighten=self._defense_state.tighten
                                * jnp.float32(cfg.watchdog_tighten))
            self._defense_state = ds
        self._srv_lr = self._srv_lr * jnp.float32(cfg.watchdog_lr_decay)
        self.total_client_reward = e.reward
        self._last_eval = e.last_eval
        self._wd_loss_ema = None
        self._wd_acc_peak = float("-inf")
        self._wd_healthy = False
        self.watchdog_totals["rollbacks"] = self._wd_rollbacks
        obs.OBS.counter("watchdog/rollbacks")
        obs.OBS.event("watchdog", name="rollback", round=bad_round,
                      restored_round=e.round, reason=reason,
                      rollbacks=self._wd_rollbacks)

    # -- crash tolerance -----------------------------------------------
    def _ckpt_tree(self) -> Dict[str, Any]:
        """Everything array-valued the round loop's future depends on.
        The in-flight FedBuff late buffer is deliberately NOT saved: a
        crash loses updates that never folded into the model, which is
        exactly FedBuff's semantics for a server restart."""
        tree: Dict[str, Any] = {
            "params": self.params, "state": self.state, "key": self.key,
            # int32: restore round-trips leaves through jnp, which would
            # silently narrow int64 under default (x64-off) jax config
            "host_history": self._host_history.astype(np.int32)}
        if self.dynamics:
            tree["dyn_avail"] = self.dyn_state.avail
            tree["dyn_key"] = self._dyn_key
        if self.defended:
            tree["defense_state"] = self._defense_state
        if self._watchdog:
            tree["server_lr"] = self._srv_lr
        return tree

    def save_checkpoint(self, path: str, step: int) -> None:
        """Persist server params + selection/dynamics/defense state so a
        crashed run resumes from the last boundary (repro.checkpoint.io);
        host-side rng state and reward tally ride the json manifest."""
        from repro.checkpoint import io as CKPT
        extra: Dict[str, Any] = {
            "total_client_reward": self.total_client_reward,
            # the active selection scheme rides the manifest so a resume
            # under a different --scheme-select fails loudly instead of
            # silently diverging (the restored scheme_state pytree and
            # the key-consumption pattern are both scheme-shaped)
            "scheme_select": self.cfg.scheme_select}
        if self._watchdog:
            extra["watchdog_rollbacks"] = self._wd_rollbacks
        if self.dynamics:
            # the replacement sampler's host rng state is json-friendly
            # (PCG64 state dict of ints) — resumed draws continue the
            # exact chain a continuous run would have used
            extra["dyn_rng_state"] = self._dyn_rng.bit_generator.state
        with obs.span("run/checkpoint", step=step):
            CKPT.save(path, self._ckpt_tree(), step=step, extra=extra)

    def load_checkpoint(self, path: str) -> int:
        """Restore a :meth:`save_checkpoint` snapshot and return the next
        round index.  Stage-1 clustering must NOT be re-run afterwards:
        the restored key already reflects its chain consumption and the
        cluster ids live in the restored SelectionState.

        Raises ValueError when the snapshot's manifest records a
        different selection scheme than this server's
        ``cfg.scheme_select``: the checkpointed scheme_state pytree and
        key chain are scheme-shaped, so continuing under another scheme
        would silently diverge (or crash deep inside restore with a
        structure mismatch) — the manifest is checked FIRST."""
        from repro.checkpoint import io as CKPT
        manifest0 = path.removesuffix(".npz") + ".json"
        if os.path.exists(manifest0):
            with open(manifest0) as f:
                saved = (json.load(f).get("extra") or {}).get(
                    "scheme_select", "paper")
            if saved != self.cfg.scheme_select:
                raise ValueError(
                    f"checkpoint {path!r} was written by selection scheme "
                    f"{saved!r} but this run uses --scheme-select "
                    f"{self.cfg.scheme_select!r}; resume with "
                    f"--scheme-select {saved} or start a fresh run")
        tree, step = CKPT.restore(path, self._ckpt_tree())
        self.params = tree["params"]
        self.state = tree["state"]
        self.key = tree["key"]
        self._host_history = np.asarray(
            obs.device_get(tree["host_history"]), np.int64)
        if self.dynamics:
            self.dyn_state = DYN.DynamicsState(avail=tree["dyn_avail"])
            self._dyn_key = tree["dyn_key"]
            self._host_avail = np.asarray(
                obs.device_get(tree["dyn_avail"]), bool)
            self._host_clusters = np.asarray(
                obs.device_get(self.state.clusters), np.int64)
        if self.defended:
            self._defense_state = tree["defense_state"]
        if self._watchdog:
            self._srv_lr = tree["server_lr"]
        manifest = path.removesuffix(".npz") + ".json"
        if os.path.exists(manifest):
            with open(manifest) as f:
                extra = json.load(f).get("extra") or {}
            self.total_client_reward = float(
                extra.get("total_client_reward", 0.0))
            st = extra.get("dyn_rng_state")
            if self.dynamics and st is not None:
                self._dyn_rng.bit_generator.state = st
            if self._watchdog:
                self._wd_rollbacks = int(
                    extra.get("watchdog_rollbacks", 0))
                self.watchdog_totals["rollbacks"] = self._wd_rollbacks
        return step

    def run_round(self, t: int) -> RoundLog:
        """One synchronous FL round (dispatch + immediate flush) — the
        single-round API; the async pipeline lives in :meth:`run`."""
        self._dispatch_round(t, self._eval_due(t))
        self._flush_pending()
        return self.logs[-1]

    # ------------------------------------------------------------------
    def run(self, rounds: Optional[int] = None, verbose: bool = False,
            audit_sync: bool = False, audit_warm_rounds: int = 2,
            checkpoint_every: int = 0,
            checkpoint_path: Optional[str] = None, resume: bool = False):
        """The async round loop.  ``verbose`` prints a progress line
        every 5 rounds showing the *last drained* eval (NaN until one
        drains) — verbosity must never change the measured eval cadence
        (it used to force an eval at every print boundary, so logs and
        params depended on the flag; regression-tested in
        tests/test_obs.py).  ``audit_sync`` wraps every dispatch from
        round ``audit_warm_rounds`` on in the transfer-guard sync
        auditor: an implicit host transfer inside the warm loop raises
        at the offending op (obs.sync_audit).

        ``checkpoint_every`` > 0 (with a ``checkpoint_path``) snapshots
        params + server state every that many rounds; ``resume`` picks
        the run back up from an existing snapshot — stage-1 clustering
        is skipped because the restored state already carries its result
        (and the restored key its chain consumption), so a resumed
        dynamics-free run walks the remaining rounds bit-identically to
        an uninterrupted one (tests/test_checkpoint.py)."""
        start = 0
        if resume and checkpoint_path is not None and os.path.exists(
                checkpoint_path.removesuffix(".npz") + ".npz"):
            start = self.load_checkpoint(checkpoint_path)
            obs.log(f"resumed checkpoint {checkpoint_path!r} "
                    f"at round {start}")
        if start == 0:
            with obs.span("run/cluster", scheme=self.cfg.scheme):
                self.cluster()
        warmup = getattr(self.runtime, "warmup", None)
        if warmup is not None:    # device runtime: compile every class
            with obs.span("run/warmup"):
                warmup(self.params)
        if self._watchdog and not self._wd_ring:
            # seed the ring with the pre-training state so even a
            # round-0 divergence has a healthy entry to roll back to
            self._wd_snapshot(start - 1)
        T = rounds if rounds is not None else self.cfg.rounds
        for t in range(start, T):
            printing = verbose and (t % 5 == 0 or t == T - 1)
            final = t == T - 1
            eval_now = self._eval_due(t, final=final)
            if audit_sync and t >= audit_warm_rounds:
                with obs.sync_audit():
                    self._dispatch_round(t, eval_now, final=final)
            else:
                self._dispatch_round(t, eval_now, final=final)
            if self._watchdog and eval_now and not printing:
                # the detector lives at flush boundaries: with the
                # watchdog on, every eval round IS a flush boundary so a
                # divergence is caught within one eval cadence
                self._flush_pending()
            if printing:
                self._flush_pending()
                log = self.logs[-1]
                acc, loss = self._last_eval
                obs.log(f"  round {t:3d} acc={acc:.3f} "
                        f"loss={loss:.3f} "
                        f"E_std={log.energy_std:.3f} "
                        f"bid={log.mean_bid:.3f} "
                        f"vds_gap={log.vds_gap:.3f}")
            if (checkpoint_every > 0 and checkpoint_path is not None
                    and (t + 1) % checkpoint_every == 0 and not final):
                # flush first so the log stream is consistent up to the
                # snapshot boundary a resumed run continues from
                self._flush_pending()
                self.save_checkpoint(checkpoint_path, t + 1)
        self._flush_pending()
        return self.logs
