"""Fused round control plane: the paper's per-round stage-2 pipeline
(cost -> Nash bids -> s_min -> per-cluster reverse auction -> rewards ->
energy/history update -> metrics) as ONE compiled program.

Three entry points, all sharing the same round body so they stay
equivalent by construction:

  * :func:`make_round_step` — a jitted ``(state, key) -> (state, win,
    metrics)`` step for the live FL loop (FederatedServer.run_round);
    everything the RoundLog needs (energy std, mean winning bid, reward
    sums, vds-gap from precomputed per-client label histograms) is
    computed on device, so the server does at most ONE host transfer for
    the control plane per round.
  * :func:`simulate_rounds` — a ``lax.scan``-over-rounds *selection-only*
    fast path: T rounds of the full auction/energy dynamics run as one
    compiled program with per-round metrics buffered on device and
    fetched once.  This is what makes N=100k-1M clients x thousands of
    rounds tractable for the Fig 9/10-style experiments
    (``benchmarks/run.py --only selection``; ``launch.train --mode
    selection``).
  * :func:`simulate_rounds_reference` — the seed per-round Python path
    (eager select/update with a host sync per round), kept verbatim as
    the equivalence oracle and benchmark baseline.  Winner masks, energy
    trajectories and history are bit-identical with the scan path under
    the same key stream (tests/test_rounds.py).

The key stream is the seed loop's split chain — ``key, k = split(key)``
per round — carried through the scan, so the two paths consume identical
per-round keys.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import FLConfig
from repro.core import auction as A
from repro.core import energy as E
from repro.core import schemes as SCH
from repro.core import selection as SEL
from repro.core.virtual_dataset import virtual_dataset_gap_device

Metrics = Dict[str, jnp.ndarray]


def round_rewards(win: jnp.ndarray, bids: jnp.ndarray,
                  local_sizes: jnp.ndarray, cfg: FLConfig
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-client rewards + server share under cfg.reward_model (eq 15/16).
    Zero-winner rounds pay exactly zero on both sides (guards in
    repro.core.auction)."""
    if cfg.reward_model == "bid_share":
        return A.reward_bid_share(win, bids, cfg)
    return A.reward_sample_share(win, local_sizes, cfg), jnp.float32(0.0)


def _round_body(state: SEL.SelectionState, key, cfg: FLConfig,
                count_hists: Optional[jnp.ndarray],
                global_hist: Optional[jnp.ndarray],
                winners_impl: str = "segmented",
                avail: Optional[jnp.ndarray] = None
                ) -> Tuple[SEL.SelectionState, jnp.ndarray, Metrics]:
    """One full control-plane round. Pure function of (state, key) —
    traced identically by the jitted step, the scan path and the eager
    reference (modulo ``winners_impl``, whose implementations are
    bit-identical), which is what makes the three bit-comparable.
    ``avail`` is the fleet-dynamics availability mask (None = every
    dynamics-free trace is unchanged)."""
    obs.jax_stats.note_trace("round_step")   # fires at (re)trace time only
    scheme = SCH.get_scheme(cfg.scheme_select)
    if state.strikes is not None and cfg.reputation_mode == "ban":
        # auction reputation, ban mode: quarantine repeat offenders
        # (strikes at or above the ban threshold) lose eligibility exactly
        # like offline clients — the pure 'random' baseline stays blind,
        # same as avail.  Price mode drops the hard gate: strikes inflate
        # the effective bid inside each scheme's ranking step instead
        # (auction.effective_bids), so a tainted client can still win by
        # underbidding.
        trust = state.strikes < cfg.strike_threshold
        avail = trust if avail is None else (avail & trust)
    win, info = scheme.select(state, cfg, key, winners_impl=winners_impl,
                              avail=avail)
    bids = info["bids"]
    client_r, server_r = round_rewards(win, bids, state.local_sizes, cfg)
    new_state = SEL.update_after_round(state, win, cfg)
    scheme_state, scheme_metrics = scheme.update_state(
        state, new_state, cfg, win, info, client_r)
    new_state = dataclasses.replace(new_state, scheme_state=scheme_state)

    nwin = win.sum()
    winning_bids = jnp.where(win, bids, 0.0)
    metrics: Metrics = {
        "num_winners": nwin,
        "mean_bid": jnp.where(
            nwin > 0, winning_bids.sum() / jnp.maximum(nwin, 1), 0.0),
        "client_reward_sum": client_r.sum(),
        "server_reward": jnp.asarray(server_r, jnp.float32),
        "s_min": jnp.asarray(info.get("s_min", 0), jnp.int32),
        "vds_gap": (virtual_dataset_gap_device(win, count_hists, global_hist)
                    if count_hists is not None else jnp.float32(0.0)),
        # selection fairness across the zoo: dispersion of cumulative
        # participation counts (0 = perfectly even) — comparable between
        # schemes because every scheme shares the same history update
        "fairness_hist_std": jnp.std(
            new_state.history.astype(jnp.float32)),
    }
    metrics.update(E.energy_stats(new_state.residual))
    metrics.update(scheme_metrics)
    if state.strikes is not None:
        metrics["num_banned"] = (
            state.strikes >= cfg.strike_threshold).sum()
        # continuous trust score 1/(1+strikes) in (0, 1] — the scalar the
        # obs stream tracks for reputation pricing (1.0 = clean record)
        trust_score = 1.0 / (1.0 + state.strikes)
        metrics["trust_mean"] = trust_score.mean()
        metrics["trust_min"] = trust_score.min()
    return new_state, win, metrics


@partial(jax.jit, static_argnames=("cfg", "winners_impl"))
def _round_step_jit(state: SEL.SelectionState, key, count_hists, global_hist,
                    cfg: FLConfig, winners_impl: str):
    return _round_body(state, key, cfg, count_hists, global_hist,
                       winners_impl)


def _round_body_dyn(state: SEL.SelectionState, dyn_state, key, dyn_key,
                    cfg: FLConfig, count_hists, global_hist,
                    winners_impl: str):
    """The dynamics-composed round: selection sees the churn process's
    round-start availability, then the fault model classifies every
    winner (completed/late/dropped) and the staleness counter ages.  The
    control plane's energy/history update stays winner-based (a dropped
    client still burned its round budget committing — the upper-bound
    accounting DESIGN.md §Fleet dynamics motivates)."""
    from repro.sim import dynamics as DYN
    new_state, win, metrics = _round_body(
        state, key, cfg, count_hists, global_hist, winners_impl,
        avail=dyn_state.avail)
    k_fault = jax.random.fold_in(dyn_key, 0)
    outcome, lat, new_avail = DYN.fault_step(
        cfg, k_fault, win, dyn_state.avail, state.residual,
        state.local_sizes)
    stale = DYN.update_staleness(state.staleness, outcome)
    new_state = dataclasses.replace(new_state, staleness=stale)
    metrics = dict(metrics)
    metrics.update(DYN.outcome_metrics(outcome, stale))
    nwin = jnp.maximum(metrics["num_winners"], 1)
    metrics["mean_latency"] = jnp.where(win, lat, 0.0).sum() / nwin
    metrics["num_avail"] = new_avail.sum()
    return (new_state, DYN.DynamicsState(avail=new_avail), win, outcome,
            metrics)


@partial(jax.jit, static_argnames=("cfg", "winners_impl"))
def _round_step_dyn_jit(state: SEL.SelectionState, dyn_state, key, dyn_key,
                        count_hists, global_hist, cfg: FLConfig,
                        winners_impl: str):
    return _round_body_dyn(state, dyn_state, key, dyn_key, cfg,
                           count_hists, global_hist, winners_impl)


def make_round_step(cfg: FLConfig,
                    count_hists: Optional[np.ndarray] = None,
                    global_hist: Optional[np.ndarray] = None,
                    winners_impl: str = "segmented",
                    dynamics: bool = False):
    """Compile one ``(state, key) -> (new_state, win, metrics)`` round
    program for the live FL loop. ``count_hists`` is the (N, num_classes)
    per-client label-count matrix (virtual_dataset.client_count_histograms);
    with it the vds-gap is computed on device, otherwise it logs 0.

    With ``dynamics=True`` the returned step fuses the fleet fault model
    (repro.sim.dynamics) into the same program and has the extended
    signature ``(state, dyn_state, key, dyn_key) -> (new_state,
    new_dyn_state, win, outcome, metrics)`` — ``dyn_key`` comes from the
    server's DEDICATED dynamics chain, never the selection chain."""
    ch = None if count_hists is None else jnp.asarray(count_hists,
                                                      jnp.float32)
    gh = None if global_hist is None else jnp.asarray(global_hist,
                                                      jnp.float32)

    if dynamics:
        def round_step_dyn(state: SEL.SelectionState, dyn_state, key,
                           dyn_key):
            return _round_step_dyn_jit(state, dyn_state, key, dyn_key,
                                       ch, gh, cfg, winners_impl)

        return round_step_dyn

    def round_step(state: SEL.SelectionState, key):
        return _round_step_jit(state, key, ch, gh, cfg, winners_impl)

    return round_step


# ----------------------------------------------------------------------
# scan-over-rounds selection-only simulation
# ----------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg", "rounds", "record_wins"))
def _simulate_scan(state: SEL.SelectionState, key, count_hists, global_hist,
                   cfg: FLConfig, rounds: int, record_wins: bool):
    def body(carry, _):
        state, key = carry
        key, k = jax.random.split(key)           # the seed loop's chain
        new_state, win, metrics = _round_body(state, k, cfg, count_hists,
                                              global_hist)
        out = (win, metrics) if record_wins else metrics
        return (new_state, key), out

    (final_state, _), ys = jax.lax.scan(body, (state, key), None,
                                        length=rounds)
    if record_wins:
        wins, metrics = ys
        return final_state, metrics, wins
    return final_state, ys, None


def simulate_rounds(state: SEL.SelectionState, cfg: FLConfig, key,
                    rounds: int,
                    count_hists: Optional[np.ndarray] = None,
                    global_hist: Optional[np.ndarray] = None,
                    record_wins: bool = False):
    """Run ``rounds`` rounds of the full selection/auction/energy dynamics
    as ONE compiled lax.scan program (no stage-3 training — the
    selection-only fast path for Fig 9/10-style experiments).

    Returns ``(final_state, metrics, wins)`` where ``metrics`` maps each
    round metric to a ``(rounds,)`` device buffer (fetch once with
    ``jax.device_get``) and ``wins`` is the ``(rounds, N)`` bool winner
    masks when ``record_wins`` (default off — at N=1M x T=1k that buffer
    alone is 1 GB; metrics are a few scalars per round regardless of N).
    """
    ch = None if count_hists is None else jnp.asarray(count_hists,
                                                      jnp.float32)
    gh = None if global_hist is None else jnp.asarray(global_hist,
                                                      jnp.float32)
    return _simulate_scan(state, key, ch, gh, cfg, int(rounds),
                          bool(record_wins))


def simulate_rounds_reference(state: SEL.SelectionState, cfg: FLConfig, key,
                              rounds: int,
                              count_hists: Optional[np.ndarray] = None,
                              global_hist: Optional[np.ndarray] = None,
                              record_wins: bool = False):
    """The seed per-round Python path: one round dispatched at a time
    using the per-cluster argsort loop (``winners_impl="loop"``, the seed
    auction implementation) with the per-round host syncs the pre-fusion
    server paid (metrics pulled every round). The step itself is jitted —
    XLA's algebraic simplifier rewrites float expressions under jit (e.g.
    ``x * rho / 100``), so a fully-eager loop could never bit-match a
    compiled path; jitting the step keeps the comparison about *fusion
    across rounds*, and keeps this the exact-equality oracle. Same
    signature and return shape as :func:`simulate_rounds`; also the
    baseline the ``--only selection`` benchmark measures the fused path
    over."""
    ch = None if count_hists is None else jnp.asarray(count_hists,
                                                      jnp.float32)
    gh = None if global_hist is None else jnp.asarray(global_hist,
                                                      jnp.float32)
    wins, metric_rows = [], []
    for _ in range(int(rounds)):
        key, k = jax.random.split(key)
        state, win, metrics = _round_step_jit(state, k, ch, gh, cfg, "loop")
        metric_rows.append(jax.device_get(metrics))   # per-round host sync
        if record_wins:
            wins.append(np.asarray(win))
    metrics_np = {name: np.stack([m[name] for m in metric_rows])
                  for name in metric_rows[0]} if metric_rows else {}
    if not record_wins:
        return state, metrics_np, None
    wins_np = (np.stack(wins) if wins
               else np.zeros((0, state.clusters.shape[0]), bool))
    return state, metrics_np, wins_np


# ----------------------------------------------------------------------
# synthetic fleets (million-client states without a dataset)
# ----------------------------------------------------------------------

def synthetic_fleet(cfg: FLConfig, key, size_low: int = 100,
                    size_high: int = 1200) -> SEL.SelectionState:
    """A SelectionState for selection-only experiments at arbitrary N:
    uniform random cluster ids, Table-I-style local sizes in
    [size_low, size_high] (the paper's MNIST imbalance range at N=100),
    initial energy per cfg.init_energy_mode. Built entirely on device —
    no dataset or partitioning pass, so N=1M costs ~16 MB of state."""
    k_cl, k_en, k_sz = jax.random.split(key, 3)
    n = cfg.num_clients
    return SEL.SelectionState(
        clusters=jax.random.randint(k_cl, (n,), 0, cfg.num_clusters,
                                    jnp.int32),
        residual=E.init_energy(cfg, k_en),
        history=jnp.zeros((n,), jnp.int32),
        local_sizes=jax.random.randint(k_sz, (n,), size_low, size_high + 1,
                                       jnp.int32),
        scheme_state=SCH.init_scheme_state(cfg),
    )
