from repro.optim.optimizers import (  # noqa: F401
    adamw, apply_updates, fedprox_grad, sgd, OptState)
