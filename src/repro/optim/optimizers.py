"""Minimal optimizer library (no optax offline): SGD(+momentum), AdamW,
and the FedProx proximal-gradient wrapper.

All optimizers are (init, update) pairs over pytrees; state mirrors the
parameter tree so the sharding rules apply unchanged (opt_state_specs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any          # first moment / momentum (pytree or None)
    nu: Any          # second moment (pytree or None)


def _zeros_like_f32(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ----------------------------------------------------------------------

def sgd(lr: float, momentum: float = 0.0, weight_decay: float = 0.0):
    def init(params) -> OptState:
        mu = _zeros_like_f32(params) if momentum else None
        return OptState(jnp.zeros((), jnp.int32), mu, None)

    def update(grads, state: OptState, params) -> Tuple[Any, OptState]:
        if weight_decay:
            grads = jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(g.dtype),
                grads, params)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                              state.mu, grads)
            upd = jax.tree.map(lambda m: -lr * m, mu)
        else:
            mu = None
            upd = jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads)
        return upd, OptState(state.step + 1, mu, None)

    return init, update


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0):
    def init(params) -> OptState:
        return OptState(jnp.zeros((), jnp.int32), _zeros_like_f32(params),
                        _zeros_like_f32(params))

    def update(grads, state: OptState, params) -> Tuple[Any, OptState]:
        step = state.step + 1
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2)
            * jnp.square(g.astype(jnp.float32)), state.nu, grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)
        upd = jax.tree.map(
            lambda m, v, p: -lr * ((m / c1) / (jnp.sqrt(v / c2) + eps)
                                   + weight_decay * p.astype(jnp.float32)),
            mu, nu, params)
        return upd, OptState(step, mu, nu)

    return init, update


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)


# ----------------------------------------------------------------------

def fedprox_grad(grads, params, global_params, mu: float):
    """FedProx [24]: local objective f_k(w) + mu/2 ||w - w_t||^2 — add
    mu (w - w_t) to the local gradient."""
    return jax.tree.map(
        lambda g, p, w: g + mu * (p.astype(jnp.float32)
                                  - w.astype(jnp.float32)).astype(g.dtype),
        grads, params, global_params)
