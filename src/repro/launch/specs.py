"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, no device allocation. The dry-run lowers against these.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as MD


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Inputs of train_step: the token batch (+ stub modality embeddings)."""
    B, S = shape.global_batch, shape.seq_len
    batch = {
        "tokens": sds((B, S), jnp.int32),
        "labels": sds((B, S), jnp.int32),
        "mask": sds((B, S), jnp.float32),
    }
    if cfg.num_prefix_tokens:  # vlm: projected patch embeddings (stub)
        batch["prefix_embeddings"] = sds(
            (B, cfg.num_prefix_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.is_encdec:          # audio: conv/mel frame embeddings (stub)
        batch["encoder_frames"] = sds(
            (B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Inputs of serve_step: one token per sequence + current position."""
    B = shape.global_batch
    return {
        "tokens": sds((B,), jnp.int32),
        "pos": sds((), jnp.int32),
    }


def params_shape(cfg: ModelConfig):
    return jax.eval_shape(
        lambda k: MD.init_params(cfg, k), jax.random.PRNGKey(0))


def decode_state_shape(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: MD.init_decode_state(cfg, shape.global_batch, shape.seq_len))
