"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; smoke tests and benches must keep seeing 1 device.

Mesh semantics (DESIGN.md §5):
  * ``data``  — FSDP + batch parallelism (16-way per pod)
  * ``model`` — tensor/expert parallelism (16-way)
  * ``pod``   — federated cohorts: parameters replicated across pods, one
    cross-pod all-reduce per FL aggregation round.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist on newer releases; older ones
    default every axis to Auto anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh():
    """1x1 mesh with the production axis names — lets every pjit'd function
    run unchanged on the single CPU device for tests/examples."""
    return _make_mesh((1, 1), ("data", "model"))


def make_cohort_mesh(num_devices: int = 0):
    """Mesh for the sharded cohort runtime (repro.sim ``--runtime sharded``):
    every packed bucket's client axis is shard_map'd over ``data``, params
    stay replicated, and the weighted FedAvg partial is psum-reduced on-mesh.

    ``num_devices`` caps the data axis (0 = all local devices). With one
    device this degrades to the 1-device debug mesh, so the sharded runtime
    runs unchanged (and is tested) on a plain CPU host; CI additionally
    forces an 8-device CPU mesh via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — that flag must
    be set before first jax init (same caveat as the dry-run's 512).
    """
    n_avail = jax.local_device_count()
    n = min(num_devices, n_avail) if num_devices > 0 else n_avail
    if n <= 1:
        return make_debug_mesh()
    return _make_mesh((n, 1), ("data", "model"))


# TPU v5e hardware constants for the roofline model (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link (~ per-chip collective bw)
HBM_PER_CHIP = 16 * 2**30       # 16 GiB
