"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; smoke tests and benches must keep seeing 1 device.

Mesh semantics (DESIGN.md §5):
  * ``data``  — FSDP + batch parallelism (16-way per pod)
  * ``model`` — tensor/expert parallelism (16-way)
  * ``pod``   — federated cohorts: parameters replicated across pods, one
    cross-pod all-reduce per FL aggregation round.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh():
    """1x1 mesh with the production axis names — lets every pjit'd function
    run unchanged on the single CPU device for tests/examples."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e hardware constants for the roofline model (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link (~ per-chip collective bw)
HBM_PER_CHIP = 16 * 2**30       # 16 GiB
