"""Batched greedy-decode serving driver (reduced configs on CPU; the full
configs x decode shapes are exercised via the dry-run).

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-1.3b \
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs.registry import get_smoke_config
from repro.launch.steps import make_serve_step
from repro.models import model as MD


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--kv-dtype", default=None,
                    choices=[None, "bfloat16", "int8"])
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    obs.configure(quiet=args.quiet)

    cfg = get_smoke_config(args.arch)
    if args.kv_dtype:
        cfg = cfg.replace(kv_cache_dtype=args.kv_dtype)
    key = jax.random.PRNGKey(0)
    params = MD.init_params(cfg, key)
    B = args.batch
    cache_len = args.prompt_len + args.gen
    state = MD.init_decode_state(cfg, B, cache_len)
    if cfg.is_encdec:
        frames = jax.random.normal(
            jax.random.fold_in(key, 1), (B, cfg.encoder_seq, cfg.d_model))
        state["cross"] = MD.build_cross_cache(
            cfg, params, MD.encode(cfg, params, frames))

    serve_step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    prompts = jax.random.randint(jax.random.fold_in(key, 2),
                                 (B, args.prompt_len), 0, cfg.vocab_size)
    # prefill via teacher-forced decode steps (one-token server)
    tok = prompts[:, 0]
    for t in range(args.prompt_len - 1):
        _, state = serve_step(params, state, prompts[:, t], jnp.int32(t))
        tok = prompts[:, t + 1]

    generated = []
    t0 = time.time()
    pos = args.prompt_len - 1
    for t in range(args.gen):
        tok, state = serve_step(params, state, tok, jnp.int32(pos + t))
        generated.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = jnp.stack(generated, axis=1)
    obs.log(f"arch={cfg.name} batch={B} generated {args.gen} tokens/seq "
            f"in {dt:.2f}s -> {B * args.gen / dt:.1f} tok/s "
            f"(kv={cfg.kv_cache_dtype})")
    obs.log(f"sample token ids: {gen[0, :16].tolist()}")


if __name__ == "__main__":
    main()
