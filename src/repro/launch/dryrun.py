"""Multi-pod dry-run: prove the distribution config is coherent without
hardware. For every (architecture x input shape x mesh) this lowers and
compiles the real train/serve step against ShapeDtypeStruct inputs on the
production mesh, then records memory analysis, FLOPs/bytes and the
collective schedule for the roofline report.

MUST be run as a module:  PYTHONPATH=src python -m repro.launch.dryrun ...
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices; jax locks
# the device count on first init, so this precedes every other import.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import subprocess
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.configs.base import INPUT_SHAPES, SHAPES_BY_NAME, ShapeConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_serve_step, make_train_step
from repro.optim import OptState
from repro.sharding import rules as R

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _tensor_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\])")
_OPND_RE = re.compile(r"%([\w.\-]+)")


def _parse_type_bytes(ty: str) -> int:
    """bytes of 'f32[1,2,3]' or tuple '(f32[2], s32[])'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(ty):
        if dt in _DTYPE_BYTES:
            total += _tensor_bytes(dt, dims)
    return total


_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_INT_CONST_RE = re.compile(r"[su]\d+\[\]\s+constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, list]:
    comps: Dict[str, list] = {}
    cur = None
    entry = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(line)
        if m and line.rstrip().endswith("{") \
                and " = " not in line.split("(")[0]:
            cur = m.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
        elif line.strip() == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(line)
    return comps, entry


def _trip_count(cond_lines: list) -> int:
    """Heuristic trip count of a while loop: the largest integer constant in
    its condition computation (our scans compare an induction var against
    the trip count)."""
    best = 1
    for line in cond_lines:
        for m in _INT_CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


_RG_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9,{} ]+)\}\}")
_RG_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](T\(([0-9,]+)\))?")


def _crosses_pods(line: str, pod_size: int = 256) -> bool:
    """True if any replica group mixes devices from different pods (device
    ids [0, pod_size) vs [pod_size, ...)). Handles explicit and iota
    replica_groups formats."""
    m = _RG_EXPLICIT_RE.search(line)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(x) for x in grp.replace("{", "").replace("}", "")
                   .split(",") if x.strip()]
            if ids and (min(ids) < pod_size <= max(ids)):
                return True
        return False
    m = _RG_IOTA_RE.search(line)
    if m:
        import numpy as np
        ngroups, per_group = int(m.group(1)), int(m.group(2))
        total = ngroups * per_group
        if total <= pod_size:
            return False
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(total).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(5).split(",")]
            ids = ids.transpose(perm)
        groups = ids.reshape(ngroups, per_group)
        return bool(np.any((groups.min(1) < pod_size)
                           & (groups.max(1) >= pod_size)))
    return False


def _line_operand_bytes(line: str, opname: str, sym: Dict[str, int]) -> int:
    mo = re.search(rf"\b{opname}(?:-start)?\(", line)
    if not mo:
        return 0
    args = line[mo.end() - 1:]
    depth, end = 0, len(args)
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return sum(sym.get(name, 0) for name in _OPND_RE.findall(args[:end]))


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Loop-aware sum of *operand* bytes of every collective op in the
    per-device HLO.

    Two subtleties of post-optimization HLO dumps:
      * operand types are not inline -> resolve operand names against a
        per-computation symbol table of result sizes;
      * ops inside ``while`` bodies execute once per loop iteration (our
        layer stack is a ``lax.scan``!) -> walk the computation graph from
        ENTRY, multiplying by each loop's trip count (largest integer
        constant in its condition — exact for scan-generated loops).

    Returns both the executed totals and the static (body-once) totals.
    """
    comps, entry = _split_computations(hlo_text)
    out = {k: 0 for k in _COLLECTIVES}
    raw = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0

    def visit(comp: str, mult: int, seen_stack=()):
        if comp not in comps or comp in seen_stack:
            return
        lines = comps[comp]
        sym: Dict[str, int] = {}
        for line in lines:
            m = _DEF_RE.match(line)
            if m:
                sym[m.group(1)] = _parse_type_bytes(m.group(2))
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.groups()
                trips = _trip_count(comps.get(cond, []))
                visit(body, mult * trips, seen_stack + (comp,))
                continue
            for op in _COLLECTIVES:
                if f"{op}-done" in line:
                    continue
                if re.search(rf"\b{op}(?:-start)?\(", line):
                    b = _line_operand_bytes(line, op, sym)
                    out[op] += b * mult
                    raw[op] += b
                    out["count"] += 1
                    if _crosses_pods(line):
                        out["cross_pod"] = out.get("cross_pod", 0) + b * mult
                    break

    if entry:
        visit(entry, 1)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["static_total"] = sum(raw.values())
    out.setdefault("cross_pod", 0)
    return out


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _memory_analysis_dict(compiled) -> Dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    d = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            d[k] = int(v)
    if not d:
        d["repr"] = str(ma)
    return d


def _cost_analysis_dict(compiled) -> Dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and (
                "flops" in k or "bytes" in k or "utilization" in k.lower()
                or k in ("transcendentals", "optimal_seconds"))}


def model_flops(cfg, shape: ShapeConfig) -> float:
    """6 * N_active * D analytical training FLOPs (2ND for fwd-only decode)."""
    pshape = SP.params_shape(cfg)
    total = 0
    active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(pshape)[0]:
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        ps = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path)
        if "ffn/w_" in ps and cfg.num_experts:
            n = n * cfg.experts_per_token // cfg.num_experts
        active += n
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    mult = 2.0 if shape.is_decode else 6.0
    return mult * active * tokens, total


def dryrun_one(arch: str, shape_name: str, multi_pod: bool,
               kv_dtype: Optional[str] = None,
               remat: Optional[bool] = None,
               fsdp_gather: bool = False,
               remat_policy: Optional[str] = None,
               fl_local_steps: int = 0) -> Dict:
    cfg = get_config(arch)
    if kv_dtype:
        cfg = cfg.replace(kv_cache_dtype=kv_dtype)
    if remat is not None:
        cfg = cfg.replace(remat=remat)
    if fsdp_gather:
        cfg = cfg.replace(fsdp_gather_weights=True)
    if remat_policy:
        cfg = cfg.replace(remat_policy=remat_policy)
    shape = SHAPES_BY_NAME[shape_name]
    rec: Dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "kind": shape.kind, "kv_dtype": kv_dtype,
                 "fsdp_gather": fsdp_gather}

    if shape.name == "long_500k" and not cfg.supports_long_context():
        rec["status"] = "skipped"
        rec["reason"] = ("pure full-attention architecture: 500k decode "
                        "requires sub-quadratic state (DESIGN.md skip rule)")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    axis_sizes = R.mesh_axis_sizes(mesh)
    n_dev = mesh.devices.size
    t0 = time.time()

    pshape = SP.params_shape(cfg)
    pspecs = R.sanitize_specs(R.param_specs(cfg, pshape), pshape, axis_sizes)
    pshard = _named(mesh, pspecs)

    if fl_local_steps and shape.kind == "train" and multi_pod:
        # the paper's I local rounds per aggregation (eq 5-8) mapped onto
        # pods-as-cohorts: I per-pod SGD steps, then ONE cross-pod FedAvg
        # all-reduce. Cross-pod bytes per local step drop ~I x.
        from repro.launch.steps import make_fl_round_step
        n_cohorts = 2
        rstep = make_fl_round_step(cfg, local_steps=fl_local_steps,
                                   n_cohorts=n_cohorts)
        batch1 = SP.train_input_specs(cfg, shape)
        batch = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                (n_cohorts, fl_local_steps,
                 s.shape[0] // n_cohorts) + s.shape[1:], s.dtype), batch1)
        bspec1 = R.train_batch_specs(cfg, multi_pod=False)
        bspecs = jax.tree.map(lambda s: P("pod", None, *tuple(s)), bspec1,
                              is_leaf=lambda x: isinstance(x, P))
        bshard = _named(mesh, bspecs)
        pshape_c = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_cohorts,) + s.shape, s.dtype),
            pshape)
        pspecs_c = jax.tree.map(lambda s: P("pod", *tuple(s)), pspecs,
                                is_leaf=lambda x: isinstance(x, P))
        pshard_c = _named(mesh, pspecs_c)
        fn = jax.jit(rstep, in_shardings=(pshard_c, bshard),
                     out_shardings=(pshard_c, NamedSharding(mesh, P())),
                     donate_argnums=(0,))
        args = (pshape_c, batch)
        rec["fl_local_steps"] = fl_local_steps
    elif shape.kind in ("train", "prefill"):
        # prefill_32k exercises the same lowered graph as a forward pass;
        # we lower the train step for train_4k and a loss-only (fwd) step
        # for prefill to keep the roofline terms honest.
        train_step, opt_init = make_train_step(cfg)
        oshape = jax.eval_shape(opt_init, pshape)
        ospecs = OptState(P(), None, None)
        oshard = OptState(NamedSharding(mesh, P()), None, None)
        bspecs = R.train_batch_specs(cfg, multi_pod)
        bshard = _named(mesh, bspecs)
        batch = SP.train_input_specs(cfg, shape)

        if shape.kind == "train":
            fn = jax.jit(
                train_step,
                in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard, NamedSharding(mesh, P())),
                donate_argnums=(0, 1))
            args = (pshape, oshape, batch)
        else:
            from repro.models.model import loss_fn
            fn = jax.jit(
                lambda p, b: loss_fn(cfg, p, b),
                in_shardings=(pshard, bshard),
                out_shardings=NamedSharding(mesh, P()))
            args = (pshape, batch)
    else:
        serve_step = make_serve_step(cfg)
        sshape = SP.decode_state_shape(cfg, shape)
        sspecs = R.sanitize_specs(
            R.decode_state_specs(cfg, sshape, shape.global_batch, axis_sizes),
            sshape, axis_sizes)
        sshard = _named(mesh, sspecs)
        dspecs = R.decode_batch_specs(cfg, shape.global_batch, multi_pod)
        fn = jax.jit(
            serve_step,
            in_shardings=(pshard, sshard,
                          NamedSharding(mesh, dspecs["tokens"]),
                          NamedSharding(mesh, P())),
            out_shardings=(NamedSharding(mesh, dspecs["tokens"]), sshard),
            donate_argnums=(1,))
        batch = SP.decode_input_specs(cfg, shape)
        args = (pshape, sshape, batch["tokens"], batch["pos"])

    with jax.sharding.set_mesh(mesh):
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = _memory_analysis_dict(compiled)
    cost = _cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    mf, n_params = model_flops(cfg, shape)

    if os.environ.get("DRYRUN_TOP_BUFFERS"):
        from collections import Counter
        sizes = Counter()
        for m in re.finditer(r"%[\w.\-]+ = ([a-z0-9]+)\[([0-9,]*)\]", hlo):
            dt, dims = m.groups()
            if dt not in _DTYPE_BYTES:
                continue
            sizes[f"{dt}[{dims}]"] = _tensor_bytes(dt, dims)
        for kk, vv in sizes.most_common(12):
            obs.log(f"    {vv/2**30:8.2f} GiB  {kk}")

    rec.update(
        status="ok",
        n_devices=int(n_dev),
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory=mem,
        cost=cost,
        collectives=coll,
        model_flops=mf,
        n_params=int(n_params),
        hlo_bytes=len(hlo),
    )
    # the two headline numbers, printed per prompt requirements
    obs.log(f"[{arch} x {shape_name} x {rec['mesh']}] "
            f"compile ok in {t_compile:.1f}s; "
            f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f} GiB/dev; "
            f"flops={cost.get('flops', 0):.3g}; "
            f"collective={coll['total']/2**20:.1f} MiB/dev")
    return rec


def result_path(arch: str, shape_name: str, multi_pod: bool,
                suffix: str = "") -> str:
    mesh = "pod2" if multi_pod else "pod1"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(
        RESULTS_DIR, f"{arch}__{shape_name}__{mesh}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or 'all'")
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in INPUT_SHAPES] + ["all"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--kv-dtype", default=None)
    ap.add_argument("--remat", default=None, choices=["on", "off"])
    ap.add_argument("--fsdp-gather", action="store_true")
    ap.add_argument("--remat-policy", default=None,
                    choices=["nothing", "save_block_out"])
    ap.add_argument("--fl-local-steps", type=int, default=0,
                    help="lower the FL round step (pods=cohorts, I local "
                         "steps, one cross-pod FedAvg); needs --multi-pod")
    ap.add_argument("--suffix", default="", help="result filename suffix")
    ap.add_argument("--subprocess-per-combo", action="store_true",
                    help="isolate each combo in a fresh process")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-combo progress lines")
    args = ap.parse_args()
    obs.configure(quiet=args.quiet)

    archs = ARCH_IDS if args.arch in (None, "all") else [args.arch]
    shapes = ([s.name for s in INPUT_SHAPES]
              if args.shape in (None, "all") else [args.shape])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shp in shapes:
            for mp in meshes:
                out = result_path(arch, shp, mp, args.suffix)
                if args.subprocess_per_combo:
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shp,
                           "--suffix", args.suffix]
                    if mp:
                        cmd.append("--multi-pod")
                    if args.kv_dtype:
                        cmd += ["--kv-dtype", args.kv_dtype]
                    if args.remat:
                        cmd += ["--remat", args.remat]
                    if args.fsdp_gather:
                        cmd.append("--fsdp-gather")
                    r = subprocess.run(cmd, capture_output=True, text=True)
                    sys.stdout.write(r.stdout)
                    if r.returncode != 0:
                        failures.append((arch, shp, mp, r.stderr[-2000:]))
                    continue
                try:
                    rec = dryrun_one(arch, shp, mp, kv_dtype=args.kv_dtype,
                                     remat=(None if args.remat is None
                                            else args.remat == "on"),
                                     fsdp_gather=args.fsdp_gather,
                                     remat_policy=args.remat_policy,
                                     fl_local_steps=args.fl_local_steps)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shp,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-4000:]}
                    failures.append((arch, shp, mp, repr(e)))
                    obs.log(f"[{arch} x {shp}] FAILED: {e!r}")
                with open(out, "w") as f:
                    json.dump(rec, f, indent=1)
    if failures:
        obs.log(f"\n{len(failures)} dry-run failures:")
        for f4 in failures:
            obs.log(f"   {f4[:3]} {f4[3][:200]}")
        sys.exit(1)
    obs.log("\nall dry-runs ok")


if __name__ == "__main__":
    main()
