"""FL training driver.

Three modes:

  * ``--mode paper`` (default): the paper-faithful simulation — N edge
    clients with CNNs on a synthetic non-IID/imbalanced image dataset,
    gradient clustering + per-cluster auction selection, FedAvg/FedProx
    aggregation, energy accounting. This reproduces the paper's Figs 4-10.

  * ``--mode transformer``: FL over a registry architecture (reduced config
    on CPU; the full configs are exercised by the dry-run). Clients hold
    topic-conditional token shards; one FL round = selection -> local LM
    steps -> weighted aggregation.

  * ``--mode selection``: selection-only simulation — the full per-round
    auction/energy dynamics (cost, Nash bids, s_min, per-cluster reverse
    auction, rewards, energy/history) WITHOUT stage-3 training, run as one
    lax.scan-over-rounds compiled program (repro.core.rounds.simulate_rounds)
    over a synthetic fleet. This is the Fig 9/10-style experiment engine at
    scale: N=100k-1M clients x thousands of rounds on a laptop.

Cohort execution backend (``--runtime``, see repro/sim/):

  * ``sequential`` (default): the reference oracle — each winner trains
    in its own Python loop of jitted steps.
  * ``vectorized``: whole-cohort execution — winners are packed into
    padded, size-bucketed ``(C, steps, bs, ...)`` tensors and their local
    epochs run as one compiled vmap/scan program per bucket, with the
    weighted FedAvg aggregation fused in.  Results match ``sequential``
    up to float reassociation (same shuffles, same batch boundaries).
    Caveat: clients are bucketed by (batch size, pow2 step band) and
    padded to the bucket's max step count, so uneven cohorts pay up to
    ~2x the smallest member's steps within a band; jit retraces per
    bucket shape (padding rounds the client axis to a multiple of the
    vmap chunk width and steps to a multiple of 4 to bound the cache).
  * ``sharded``: the vectorized engine mesh-mapped across the cohort
    mesh (``--cohort-devices``, default all local devices): each
    bucket's client axis is shard_map'd over the mesh's ``data`` axis
    with replicated params and an on-mesh psum FedAvg reduction.  On a
    1-device host it degrades to the debug mesh (same program); to try
    a multi-device CPU mesh set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` BEFORE
    launching (the flag must precede first jax init — see
    launch/mesh.py).  Equivalence with ``vectorized`` (and the oracle)
    is enforced by tests/test_sim.py on both mesh shapes.
  * ``device``: the device-resident fleet pipeline (repro.sim.fleet) —
    all clients' data packed once into static capacity-class device
    tensors at init, per-round cohorts assembled as on-device gathers,
    compile-once shape policy (zero retraces after warm-up), async
    round loop.  ``--eval-every N`` evaluates test accuracy/loss only
    every N rounds (skipped rounds log NaN; the final round always
    evaluates) — eval is the deepest per-round host sync, so raising it
    lengthens the async pipeline for every runtime.

Usage:
  PYTHONPATH=src python -m repro.launch.train --mode paper \
      --scheme gradient_cluster_auction --rounds 30
  PYTHONPATH=src python -m repro.launch.train --mode paper \
      --runtime vectorized --clients 200 --rounds 30
  PYTHONPATH=src python -m repro.launch.train --mode paper \
      --runtime device --eval-every 5 --rounds 30
  PYTHONPATH=src python -m repro.launch.train --mode transformer \
      --arch qwen2-0.5b --rounds 3
  PYTHONPATH=src python -m repro.launch.train --mode selection \
      --clients 1000000 --clusters 100 --rounds 1000
  PYTHONPATH=src python -m repro.launch.train --mode paper \
      --runtime device --rounds 30 --log-jsonl runs/events.jsonl \
      --audit-sync            # structured telemetry + sync audit
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro import obs
from repro.configs.base import FLConfig
from repro.core.adapters import cnn_adapter, transformer_adapter
from repro.core.server import FederatedServer
from repro.data.partition import partition_clients
from repro.data.synthetic import make_image_dataset, make_token_dataset


def run_paper(args) -> dict:
    cfg = FLConfig(
        num_clients=args.clients, num_clusters=args.clusters,
        select_ratio=args.select_ratio, rounds=args.rounds,
        local_epochs=args.local_epochs, lr=args.lr,
        non_iid_level=args.nu, scheme=args.scheme,
        scheme_select=args.scheme_select,
        fedcs_deadline=args.fedcs_deadline,
        aggregator=args.aggregator, init_energy_mode=args.energy_mode,
        runtime=args.runtime, cohort_mesh_devices=args.cohort_devices,
        eval_every=args.eval_every, seed=args.seed,
        churn=args.churn, deadline=args.deadline,
        straggler_profile=args.straggler_profile,
        aggregation=args.aggregation, buffer_goal=args.buffer_goal,
        buffer_timeout=args.buffer_timeout,
        adversary_frac=args.adversary_frac, attack=args.attack,
        attack_scale=args.attack_scale, defense=args.defense,
        defense_mode=args.defense_mode,
        reputation_mode=args.reputation_mode,
        watchdog=args.watchdog, watchdog_ring=args.watchdog_ring)
    train, test = make_image_dataset(args.dataset,
                                     n_train=args.pool, n_test=args.pool // 6,
                                     seed=args.seed)
    clients = partition_clients(train.y, cfg, seed=args.seed)
    adapter = cnn_adapter(args.dataset)
    ntest = min(1000, len(test.x))
    srv = FederatedServer(cfg, adapter, train.x, train.y, clients,
                          {"x": test.x[:ntest], "y": test.y[:ntest]})
    t0 = time.time()
    logs = srv.run(verbose=not args.quiet, audit_sync=args.audit_sync,
                   checkpoint_every=args.checkpoint_every,
                   checkpoint_path=args.checkpoint_path,
                   resume=args.resume)
    out = {
        "mode": "paper", "scheme": args.scheme,
        "scheme_select": args.scheme_select, "nu": args.nu,
        "aggregator": args.aggregator, "dataset": args.dataset,
        "runtime": args.runtime,
        "rounds": [l.round for l in logs],
        "test_acc": [l.test_acc for l in logs],
        "test_loss": [l.test_loss for l in logs],
        "energy_std": [l.energy_std for l in logs],
        "mean_bid": [l.mean_bid for l in logs],
        "server_reward": [l.server_reward for l in logs],
        "client_reward_sum": [l.client_reward_sum for l in logs],
        "vds_gap": [l.vds_gap for l in logs],
        "wall_s": time.time() - t0,
    }
    if srv.dynamics:
        from repro.sim import dynamics as DYN
        codes = (np.concatenate(srv.outcome_log) if srv.outcome_log
                 else np.zeros((0,), np.int32))
        out["dynamics"] = {
            "churn": cfg.churn, "deadline": cfg.deadline,
            "aggregation": cfg.aggregation,
            "num_completed": int((codes == DYN.COMPLETED).sum()),
            "num_late": int((codes == DYN.LATE).sum()),
            "num_dropped": int((codes == DYN.DROPPED).sum()),
        }
    if srv.defended:
        out["defense"] = {
            "attack": cfg.attack, "adversary_frac": cfg.adversary_frac,
            "defense": cfg.defense, "defense_mode": cfg.defense_mode,
            "reputation_mode": cfg.reputation_mode,
            "num_adversaries": int(srv._adv_mask.sum()),
            "num_quarantined": srv.defense_totals["quarantined"],
            "num_screened": srv.defense_totals["screened"],
            "num_banned_final": srv.defense_totals["banned_final"],
        }
    if srv.cfg.watchdog_enabled:
        out["watchdog"] = {
            "ring": cfg.watchdog_ring,
            "rollbacks": srv.watchdog_totals["rollbacks"],
            "snapshots": srv.watchdog_totals["snapshots"],
        }
    return out


def run_transformer(args) -> dict:
    from repro.configs.registry import get_smoke_config
    mcfg = get_smoke_config(args.arch)
    cfg = FLConfig(
        num_clients=max(10, args.clients // 5), num_clusters=5,
        select_ratio=0.2, rounds=args.rounds, lr=args.lr,
        non_iid_level=args.nu, scheme=args.scheme, num_classes=10,
        scheme_select=args.scheme_select,
        fedcs_deadline=args.fedcs_deadline,
        sample_window=8, cluster_resamples=2, runtime=args.runtime,
        cohort_mesh_devices=args.cohort_devices,
        eval_every=args.eval_every, seed=args.seed,
        churn=args.churn, deadline=args.deadline,
        straggler_profile=args.straggler_profile,
        aggregation=args.aggregation, buffer_goal=args.buffer_goal,
        buffer_timeout=args.buffer_timeout)
    toks, topics = make_token_dataset(
        num_topics=10, vocab=mcfg.vocab_size, seq_len=32,
        n=cfg.num_clients * 40, seed=args.seed)
    clients = partition_clients(topics, cfg, seed=args.seed)
    adapter = transformer_adapter(mcfg)
    test_n = min(64, len(toks))
    srv = FederatedServer(cfg, adapter, toks, topics, clients,
                          {"x": toks[:test_n], "y": topics[:test_n]})
    t0 = time.time()
    logs = srv.run(verbose=not args.quiet, audit_sync=args.audit_sync)
    return {
        "mode": "transformer", "arch": args.arch, "scheme": args.scheme,
        "scheme_select": args.scheme_select, "runtime": args.runtime,
        "rounds": [l.round for l in logs],
        "test_loss": [l.test_loss for l in logs],
        "test_acc": [l.test_acc for l in logs],
        "energy_std": [l.energy_std for l in logs],
        "wall_s": time.time() - t0,
    }


def run_selection(args) -> dict:
    """Selection-only round dynamics at scale: one compiled scan over all
    rounds, metrics buffered on device and fetched once at the end."""
    import jax.numpy as jnp

    from repro.core import rounds as R
    cfg = FLConfig(
        num_clients=args.clients, num_clusters=args.clusters,
        select_ratio=args.select_ratio, rounds=args.rounds,
        scheme=args.scheme, scheme_select=args.scheme_select,
        fedcs_deadline=args.fedcs_deadline,
        init_energy_mode=args.energy_mode,
        seed=args.seed)
    key = jax.random.PRNGKey(args.seed)
    state = R.synthetic_fleet(cfg, key)
    kr = jax.random.fold_in(key, 1)
    # cold call = compile + run; a second identical call hits the jit
    # cache, so its wall clock is the warm throughput — reporting
    # rounds_per_s off the cold call buried the actual per-round rate
    # under one-time compile time (at small T compile dominates).  The
    # re-run doubles the simulation cost, so huge sweeps (1M clients x
    # 1000s of rounds) can opt out with --no-warm-rerun and take the
    # compile-inclusive rate instead.
    t0 = time.time()
    with obs.span("selection/cold", rounds=args.rounds,
                  clients=args.clients):
        final, metrics, _ = R.simulate_rounds(state, cfg, kr, args.rounds)
        metrics = obs.device_get(metrics)  # ONE host transfer for T rounds
    cold = time.time() - t0
    if args.no_warm_rerun:
        warm, compile_s = cold, None
    else:
        t1 = time.time()
        with obs.span("selection/warm", rounds=args.rounds):
            final, m2, _ = R.simulate_rounds(state, cfg, kr, args.rounds)
            jax.block_until_ready((final, m2))
        warm = time.time() - t1
        compile_s = max(cold - warm, 0.0)
    out = {
        "mode": "selection", "scheme": args.scheme,
        "scheme_select": args.scheme_select,
        "clients": args.clients, "clusters": args.clusters,
        "rounds": list(range(args.rounds)),
        "energy_std": [float(v) for v in metrics["energy_std"]],
        "mean_bid": [float(v) for v in metrics["mean_bid"]],
        "server_reward": [float(v) for v in metrics["server_reward"]],
        "client_reward_sum": [float(v)
                              for v in metrics["client_reward_sum"]],
        "num_winners": [int(v) for v in metrics["num_winners"]],
        "final_energy_mean": float(jnp.mean(final.residual)),
        "rounds_per_s": args.rounds / warm,
        "compile_s": compile_s,
        # wall_s keeps its pre-PR-4 meaning: ONE simulation incl. compile
        # (the warm timing re-run is excluded)
        "wall_s": cold,
    }
    # mirror the fetched metric columns into the obs round series (host
    # floats already in hand — no extra device traffic)
    if obs.OBS.enabled:
        for t in range(args.rounds):
            obs.OBS.record_round(
                t, energy_std=out["energy_std"][t],
                mean_bid=out["mean_bid"][t],
                server_reward=out["server_reward"][t],
                client_reward_sum=out["client_reward_sum"][t],
                num_winners=out["num_winners"][t],
                fairness_hist_std=float(metrics["fairness_hist_std"][t]),
                **{k: float(metrics[k][t]) for k in
                   ("budget_spent", "budget_remaining", "budget_queue")
                   if k in metrics})
        obs.flush()
    timing = "incl. compile" if compile_s is None \
        else f"warm; compile={compile_s:.2f}s"
    obs.log(f"selection-only: N={args.clients} T={args.rounds} "
            f"{out['rounds_per_s']:.1f} rounds/s ({timing}) "
            f"final_energy_std={out['energy_std'][-1]:.3f}", always=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="paper",
                    choices=["paper", "transformer", "selection"])
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--dataset", default="mnist",
                    choices=["mnist", "fmnist", "cifar"])
    ap.add_argument("--scheme", default="gradient_cluster_auction")
    ap.add_argument("--scheme-select", default="paper",
                    choices=["paper", "random", "fedcs",
                             "longterm_auction"],
                    help="control-plane selection scheme "
                         "(repro.core.schemes registry): 'paper' is the "
                         "pre-registry control plane (dispatching on "
                         "--scheme, bit-identical traces); 'random' picks "
                         "uniformly per cluster among available clients; "
                         "'fedcs' gates auction entry on predicted "
                         "latency meeting the deadline (arXiv:1804.08333)"
                         "; 'longterm_auction' carries a budget/payment "
                         "ledger across rounds (arXiv:2508.09181)")
    ap.add_argument("--fedcs-deadline", type=float, default=1.5,
                    help="fedcs: bid-time feasibility bound in fleet-mean "
                         "round times, used when --deadline is 0 (a "
                         "positive --deadline takes precedence so the "
                         "auction gates on the enforced deadline)")
    ap.add_argument("--aggregator", default="fedavg",
                    choices=["fedavg", "fedprox"])
    ap.add_argument("--runtime", default="sequential",
                    choices=["sequential", "vectorized", "sharded",
                             "device"],
                    help="cohort execution backend (repro.sim): "
                         "'vectorized' runs whole cohorts as one compiled "
                         "vmap/scan program per size bucket; 'sharded' "
                         "additionally maps the client axis over the "
                         "cohort mesh's data axis (shard_map + psum); "
                         "'device' keeps the fleet's data resident on "
                         "device in static capacity classes (compile "
                         "once, zero per-round host repack)")
    ap.add_argument("--cohort-devices", type=int, default=0,
                    help="data-axis size of the cohort mesh for "
                         "--runtime sharded/device (0 = all local "
                         "devices)")
    ap.add_argument("--eval-every", type=int, default=1,
                    help="evaluate test acc/loss every N rounds (skipped "
                         "rounds log NaN; the final round always "
                         "evaluates) — deepens the async round pipeline")
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--clusters", type=int, default=10)
    ap.add_argument("--select-ratio", type=float, default=0.1)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--local-epochs", type=int, default=1)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--nu", type=float, default=1.0)
    ap.add_argument("--pool", type=int, default=12000)
    ap.add_argument("--energy-mode", default="normal",
                    choices=["full", "normal"])
    ap.add_argument("--churn", type=float, default=0.0,
                    help="fleet dynamics: per-round dropout probability "
                         "of the availability churn process (0 disables "
                         "— runs stay bit-identical to the dynamics-free "
                         "path under the same seed)")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="fleet dynamics: FedCS-style round deadline in "
                         "units of the fleet-mean round time; a winner "
                         "whose sampled latency exceeds it is LATE "
                         "(0 disables deadline misses)")
    ap.add_argument("--straggler-profile", default="energy",
                    choices=["energy", "uniform", "lognormal", "none"],
                    help="latency heterogeneity for the straggler model: "
                         "'energy' ties slowdown to residual battery "
                         "(the paper's heterogeneity profile), "
                         "'uniform'/'lognormal' are energy-independent, "
                         "'none' is deterministic")
    ap.add_argument("--aggregation", default="sync",
                    choices=["sync", "buffered"],
                    help="'sync' re-weights FedAvg over the surviving "
                         "cohort; 'buffered' additionally folds LATE "
                         "winners' updates in FedBuff-style with "
                         "staleness-discounted weights at goal-count or "
                         "timeout boundaries")
    ap.add_argument("--buffer-goal", type=int, default=4,
                    help="buffered aggregation: fold once this many late "
                         "updates have arrived")
    ap.add_argument("--buffer-timeout", type=int, default=4,
                    help="buffered aggregation: fold once the oldest "
                         "arrived update is this many rounds stale")
    ap.add_argument("--adversary-frac", type=float, default=0.0,
                    help="Byzantine robustness: fraction of the fleet "
                         "corrupting its update after local training "
                         "(seed-deterministic population; 0 disables — "
                         "runs stay bit-identical to the attack-free "
                         "path)")
    ap.add_argument("--attack", default="none",
                    choices=["none", "nan", "scale", "signflip", "noise",
                             "sub_clip", "alie", "on_off"],
                    help="corruption model applied to adversarial "
                         "winners' param deltas: 'nan' poisons, 'scale' "
                         "amplifies, 'signflip' amplifies and negates, "
                         "'noise' adds gaussian noise at attack-scale x "
                         "the cohort RMS delta; ADAPTIVE attacks observe "
                         "the defense: 'sub_clip' pushes against the "
                         "honest mean at a norm just under the clip "
                         "threshold, 'alie' colludes on mean - z*std "
                         "(inside the trimmed band), 'on_off' alternates "
                         "clean/dirty phases to farm reputation")
    ap.add_argument("--attack-scale", type=float, default=25.0,
                    help="attack magnitude multiplier (scale/signflip/"
                         "noise/on_off)")
    ap.add_argument("--defense", default="none",
                    choices=["none", "clip", "trimmed", "median"],
                    help="screened robust aggregation "
                         "(repro.core.aggregation): non-finite updates "
                         "are always quarantined (and strike the "
                         "sender's auction reputation), then 'clip' "
                         "norm-clips to a running-median threshold, "
                         "'trimmed'/'median' aggregate coordinate-wise; "
                         "'none' is the undefended FedAvg baseline")
    ap.add_argument("--defense-mode", default="static",
                    choices=["static", "adaptive"],
                    help="'adaptive' auto-tunes the screen: survivor "
                         "norms outside a running median + k*MAD band "
                         "are excluded and fractionally struck, with k "
                         "tightening under attack pressure (rejection-"
                         "rate EMA) and relaxing when it falls; 'static' "
                         "is PR 8's fixed-threshold behavior")
    ap.add_argument("--reputation-mode", default="ban",
                    choices=["ban", "price"],
                    help="'ban' hard-excludes clients at or above the "
                         "strike threshold (bit-identical to the "
                         "original behavior); 'price' multiplies "
                         "(1 + gain*strikes) into the effective bid at "
                         "the auction ranking step — tainted clients "
                         "must underbid to win, payment stays on the "
                         "true bid")
    ap.add_argument("--watchdog", default="off", choices=["off", "on"],
                    help="divergence watchdog: keep a ring of healthy "
                         "snapshots, detect non-finite/spiking evals, "
                         "roll back to the newest healthy snapshot, "
                         "tighten the defense and decay the server LR "
                         "(every rollback is a 'watchdog' obs event)")
    ap.add_argument("--watchdog-ring", type=int, default=3,
                    help="watchdog: number of healthy snapshots kept in "
                         "the rollback ring")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="snapshot server params + selection/defense "
                         "state every N rounds (0 disables)")
    ap.add_argument("--checkpoint-path", default=None, metavar="PATH",
                    help="checkpoint file stem (.npz + .json manifest)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from --checkpoint-path if it exists "
                         "(skips stage-1 clustering; dynamics-free runs "
                         "continue bit-identically)")
    ap.add_argument("--no-warm-rerun", action="store_true",
                    help="selection mode: skip the second (warm) timing "
                         "run — rounds_per_s then includes compile time "
                         "(use for huge N x T sweeps where doubling the "
                         "simulation cost is not worth the clean number)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--log-jsonl", default=None, metavar="PATH",
                    help="write the structured obs event stream (round "
                         "series, spans, jax counters) as JSON lines; "
                         "validate with `python -m repro.obs.schema`")
    ap.add_argument("--log-csv", default=None, metavar="PATH",
                    help="flat CSV mirror of the obs event stream")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the whole run "
                         "for TensorBoard/Perfetto")
    ap.add_argument("--audit-sync", action="store_true",
                    help="paper/transformer: wrap warm round dispatches "
                         "in the transfer-guard sync auditor — any "
                         "implicit host transfer in the round loop "
                         "raises at the offending op")
    args = ap.parse_args()

    obs.configure(jsonl=args.log_jsonl, csv=args.log_csv,
                  quiet=args.quiet)
    with obs.maybe_profile(args.profile_dir):
        result = {"paper": run_paper, "transformer": run_transformer,
                  "selection": run_selection}[args.mode](args)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
        obs.log(f"wrote {args.out}", always=True)
    if result.get("test_acc"):
        obs.log(f"final acc={result['test_acc'][-1]:.3f} "
                f"energy_std={result['energy_std'][-1]:.3f} "
                f"wall={result['wall_s']:.0f}s", always=True)
    if "defense" in result:
        d = result["defense"]
        obs.log(f"defense {d['defense']!r} ({d['defense_mode']}, "
                f"reputation={d['reputation_mode']}) vs attack "
                f"{d['attack']!r}: adversaries={d['num_adversaries']} "
                f"quarantined={d['num_quarantined']} "
                f"screened={d['num_screened']} "
                f"banned={d['num_banned_final']}", always=True)
    if "watchdog" in result:
        w = result["watchdog"]
        obs.log(f"watchdog: rollbacks={w['rollbacks']} "
                f"snapshots={w['snapshots']} (ring={w['ring']})",
                always=True)
    obs.flush()


if __name__ == "__main__":
    main()
