"""Train / serve step builders used by the launchers and the dry-run.

``train_step`` is one FL local step (the compute hotspot of a round):
loss -> grads -> SGD update. The FL aggregation (weighted all-reduce over
the cohort axes) is ``fl_aggregate``; on the multi-pod mesh it is the one
cross-pod collective per round.

``serve_step`` is one-token greedy decode against a KV/recurrent cache.
"""
from __future__ import annotations

from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as MD
from repro.optim import apply_updates, sgd


def make_train_step(cfg: ModelConfig, lr: float = 1e-3,
                    momentum: float = 0.0):
    opt_init, opt_update = sgd(lr, momentum=momentum)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: MD.loss_fn(cfg, p, batch))(params)
        updates, new_opt = opt_update(grads, opt_state, params)
        new_params = apply_updates(params, updates)
        return new_params, new_opt, loss

    return train_step, opt_init


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, state, tokens, pos):
        logits, state = MD.decode_step(cfg, params, state, tokens, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, state

    return serve_step


def make_fl_round_step(cfg: ModelConfig, lr: float = 1e-3,
                       local_steps: int = 4, n_cohorts: int = 2):
    """One federated round mapped onto the multi-pod mesh: each pod is an
    FL cohort that runs ``local_steps`` local SGD steps (the paper's
    I >= 2 local rounds, eq 5-8) with NO cross-pod traffic, followed by ONE
    cross-pod FedAvg of the parameters. This is the paper's own
    communication-reduction technique expressed as a collective schedule:
    cross-pod bytes per local step drop ~I x vs per-step gradient sync.

    Cohorts are a vmapped leading parameter dim sharded over 'pod' (pure
    pjit — XLA:CPU's partial-manual shard_map partitioner is unreliable):
      params leaves: (n_cohorts, ...) P('pod', ...)
      batch leaves:  (n_cohorts, local_steps, B/n_cohorts, ...)
                     P('pod', None, 'data', ...)
    """

    def per_cohort(params, microbatches):
        def micro(p, mb):
            loss, g = jax.value_and_grad(
                lambda q: MD.loss_fn(cfg, q, mb))(p)
            p = jax.tree.map(
                lambda w, gw: (w.astype(jnp.float32)
                               - lr * gw.astype(jnp.float32)).astype(w.dtype),
                p, g)
            return p, loss
        return jax.lax.scan(micro, params, microbatches)

    def round_step(params_c, batch_c):
        from repro.sharding.constrain import forbid_axes
        with forbid_axes("pod"):
            params_c, losses = jax.vmap(per_cohort)(params_c, batch_c)
        # the round's single cross-pod collective: FedAvg over cohorts
        params_c = jax.tree.map(
            lambda t: jnp.broadcast_to(
                t.astype(jnp.float32).mean(0, keepdims=True),
                t.shape).astype(t.dtype),
            params_c)
        return params_c, losses.mean()

    return round_step


def fl_aggregate(params_by_cohort, weights):
    """Weighted FedAvg across the cohort (pod) axis: w = sum_k p_k w_k.
    Inside shard_map/pjit this lowers to one all-reduce over 'pod'."""
    wsum = weights.sum()

    def agg(x):
        return jnp.tensordot(weights / wsum, x.astype(jnp.float32),
                             axes=1).astype(x.dtype)

    return jax.tree.map(agg, params_by_cohort)
