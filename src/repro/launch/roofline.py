"""Roofline analysis over the dry-run artifacts.

For every (arch x shape x mesh) record produced by repro.launch.dryrun this
derives the three roofline terms (seconds per step, TPU v5e):

    compute    = FLOPs_per_chip          / 197e12 (peak bf16 FLOP/s)
    memory     = HBM_bytes_per_chip      / 819e9  (HBM bandwidth)
    collective = collective_bytes_per_chip / 50e9 (ICI link bandwidth)

Sources:
  * collective bytes — parsed from the per-device compiled HLO with
    loop-aware trip-count scaling (repro.launch.dryrun.collective_bytes):
    operand bytes of all-gather/all-reduce/reduce-scatter/all-to-all/
    collective-permute, multiplied through ``while`` trip counts (the layer
    stack is a lax.scan).
  * compute & memory — ANALYTIC napkin model (below). The compiled
    ``cost_analysis()`` numbers are also recorded, but XLA:CPU reports each
    while body ONCE (loop-body-once), so they undercount scanned models by
    ~num_layers x; they are kept in the table as `hlo_flops` for reference.
  * memory_analysis() — loop-aware buffer assignment; used for the
    fits-in-HBM check (temp bytes per device).

Analytic model (per device, bytes/flops):
  train  : FLOPs = kappa * [2·A·T + attn_quad + mixer_scan], kappa = 5
           (1 fwd + 2 bwd + 2 remat-recomputed fwd — nested remat),
           HBM = 6·P_dev (read shard fwd/bwd/remat + grad write + opt rw)
                 + 2·2·carry_saves + 12·L·B_dev·S_dev·D·b (block act rw)
                 + xent chunk logits rw
  prefill: kappa = 1, HBM = P_dev + act write
  decode : FLOPs = 2·A_tok·B + attn cache dot; HBM = P_dev + cache rw
           (decode is the textbook memory-bound regime: whole model + cache
           read per token)

MODEL_FLOPS = 6·N_active·D_tokens (train) or 2·N_active per token (decode);
useful_ratio = MODEL_FLOPS / (analytic_flops x chips) exposes remat/causal/
padding waste.

Run:  PYTHONPATH=src python -m repro.launch.roofline [--mesh pod1]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from repro import obs
from repro.configs.base import SHAPES_BY_NAME
from repro.configs.registry import get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


def load_records(mesh: str = "pod1", suffix: str = "") -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(
            RESULTS_DIR, f"*__{mesh}{suffix}.json"))):
        name = os.path.basename(f)
        if suffix == "" and name.count("__") != 2:
            continue   # skip suffixed variants when loading baselines
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


# ----------------------------------------------------------------------
# analytic napkin model
# ----------------------------------------------------------------------

def _param_counts(cfg):
    """(total, active, embed) parameter counts (active: MoE top-k only)."""
    import jax
    from repro.launch import specs as SP
    pshape = SP.params_shape(cfg)
    total = active = embed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(pshape)[0]:
        n = 1
        for s in leaf.shape:
            n *= s
        ps = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path)
        total += n
        if "embed" in ps:
            embed += n
            continue
        if "ffn/w_" in ps and cfg.num_experts:
            n = n * cfg.experts_per_token // cfg.num_experts
        active += n
    return total, active, embed


def analytic_terms(cfg, shape, n_dev: int, axis=(16, 16)) -> Dict:
    nd, nm = axis
    B, S = shape.global_batch, shape.seq_len
    D = cfg.d_model
    L = cfg.num_layers
    n_attn = sum(b.mixer == "attn" for b in cfg.cycle) * cfg.num_groups \
        + cfg.encoder_layers
    hd = cfg.resolved_head_dim
    H = cfg.num_heads
    total, active, embed = _param_counts(cfg)
    pbytes = 2  # bf16

    B_dev = max(B // nd, 1)
    P_dev = total * pbytes / n_dev

    if shape.kind == "decode":
        T = B                         # one token per sequence
        win = cfg.sliding_window or S
        cache_tok = min(S, win)
        flops = 2 * active * T
        flops += 4 * B * cache_tok * H * hd * n_attn     # q·K and p·V
        # recurrent mixers: state update ~ d_inner*d_state per token
        n_rec = sum(b.mixer in ("mamba", "mlstm", "slstm")
                    for b in cfg.cycle) * cfg.num_groups
        flops += 6 * B * cfg.mamba_d_inner * cfg.mamba_d_state * n_rec
        flops_dev = flops / n_dev
        kv_itemsize = {"int8": 1, "bfloat16": 2, "float16": 2,
                       "float32": 4}.get(cfg.resolved_kv_cache_dtype, 2)
        cache_bytes = 2 * n_attn * B * cache_tok * cfg.num_kv_heads * hd \
            * kv_itemsize
        hbm_dev = P_dev + cache_bytes / n_dev * 2 + 2 * B_dev * D * L * 4
        kappa_desc = "decode"
    else:
        T = B * S
        fwd = 2 * active * T
        fwd += 4 * B * S * S * H * hd * n_attn           # full-block flash
        n_mamba = sum(b.mixer == "mamba" for b in cfg.cycle) * cfg.num_groups
        fwd += 10 * T * cfg.mamba_d_inner * cfg.mamba_d_state * n_mamba
        n_mlstm = sum(b.mixer == "mlstm" for b in cfg.cycle) * cfg.num_groups
        fwd += 4 * B * S * 256 * D * n_mlstm             # chunkwise quad
        if shape.kind == "train":
            kappa = 5.0   # fwd + 2x bwd + 2x remat recompute
            kappa_desc = "train(k=5)"
        else:
            kappa = 1.0
            kappa_desc = "prefill"
        flops_dev = kappa * fwd / n_dev
        # HBM traffic
        act = 12 * L * B_dev * (S // (nm if shape.kind == "train" else 1)) \
            * D * pbytes
        carry = 2 * 2 * L * B_dev * max(S // nm, 1) * D * pbytes
        xent = 2 * 2 * B_dev * S * (cfg.vocab_size / nm) * 4 \
            if shape.kind == "train" else 0
        hbm_dev = (6 if shape.kind == "train" else 1) * P_dev \
            + act + carry + xent

    return {
        "analytic_flops_dev": flops_dev,
        "analytic_hbm_dev": hbm_dev,
        "kappa": kappa_desc,
        "params_total": total,
        "params_active": active,
    }


def model_flops(cfg, shape) -> float:
    total, active, _ = _param_counts(cfg)
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    # fwd-only shapes (prefill, decode) do 2·A·T useful FLOPs; training 6·A·T
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * active * tokens


# ----------------------------------------------------------------------

def roofline_terms(rec: Dict) -> Dict:
    if rec.get("status") != "ok":
        return {"status": rec.get("status", "missing"),
                "reason": rec.get("reason", rec.get("error", ""))[:100]}
    cfg = get_config(rec["arch"])
    if rec.get("kv_dtype"):
        cfg = cfg.replace(kv_cache_dtype=rec["kv_dtype"])
    shape = SHAPES_BY_NAME[rec["shape"]]
    n_dev = rec.get("n_devices", 256)
    ana = analytic_terms(cfg, shape, n_dev)
    coll = rec.get("collectives", {}).get("total", 0)
    t_comp = ana["analytic_flops_dev"] / PEAK_FLOPS_BF16
    t_mem = ana["analytic_hbm_dev"] / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    useful = mf / (ana["analytic_flops_dev"] * n_dev)
    step_s = max(terms.values())
    return {
        "status": "ok",
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "useful_ratio": useful,
        "mfu": (mf / n_dev / PEAK_FLOPS_BF16) / max(step_s, 1e-12),
        "temp_gib": rec.get("memory", {}).get("temp_size_in_bytes", 0) / 2**30,
        "collective_mib": coll / 2**20,
        "hlo_flops_bodyonce": rec.get("cost", {}).get("flops", 0),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2"])
    ap.add_argument("--suffix", default="")
    ap.add_argument("--json-out", default=None)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    obs.configure(quiet=args.quiet)

    recs = load_records(args.mesh, args.suffix)
    rows = []
    hdr = (f"{'arch':22s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
           f"{'collective':>10s} {'dominant':>10s} {'useful':>7s} "
           f"{'MFU':>7s} {'tempGiB':>8s}")
    obs.log(hdr)
    obs.log("-" * len(hdr))
    for rec in recs:
        t = roofline_terms(rec)
        row = {"arch": rec["arch"], "shape": rec["shape"],
               "mesh": rec["mesh"], **t}
        rows.append(row)
        if t.get("status") != "ok":
            obs.log(f"{rec['arch']:22s} {rec['shape']:12s} "
                    f"-- {t['status']}: {t.get('reason','')}")
            continue
        obs.log(f"{rec['arch']:22s} {rec['shape']:12s} "
                f"{t['compute_s']*1e3:8.2f}m {t['memory_s']*1e3:8.2f}m "
                f"{t['collective_s']*1e3:9.2f}m {t['dominant']:>10s} "
                f"{t['useful_ratio']:7.2%} {t['mfu']:7.2%} "
                f"{t['temp_gib']:8.2f}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
