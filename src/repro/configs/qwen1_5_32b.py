"""qwen1.5-32b [dense] — 64L d_model=5120 40H (kv=40, MHA) d_ff=27392
vocab=152064 — QKV bias [hf:Qwen/Qwen1.5-0.5B family]."""
from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    cycle=(BlockSpec("attn", "mlp"),),
    source="hf:Qwen/Qwen1.5-0.5B",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="qwen1.5-32b-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, d_ff=256, vocab_size=256, dtype="float32",
        remat=False)
