"""Config system for the repro framework.

Two config families:

* :class:`ModelConfig` — architecture description for the assigned
  architecture pool (dense / moe / ssm / hybrid / encdec(audio) / vlm).
  Every architecture is described by a *cycle* of (mixer, ffn) block kinds
  repeated ``num_layers // len(cycle)`` times so that heterogeneous stacks
  (jamba's 7:1 mamba:attn interleave, xlstm's sLSTM/mLSTM mix) lower through
  a single ``lax.scan`` over homogeneous groups.

* :class:`FLConfig` — the paper's federated-learning system knobs (clients,
  clusters, auction constants of Table I, non-IID level, energy model).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

# Block kinds usable in a cycle. mixer: how tokens mix along the sequence;
# ffn: the per-token channel mixer.
MIXERS = ("attn", "mamba", "mlstm", "slstm")
FFNS = ("mlp", "moe", "none")


@dataclass(frozen=True)
class BlockSpec:
    """One position in an architecture's layer cycle."""

    mixer: str = "attn"
    ffn: str = "mlp"

    def __post_init__(self):
        assert self.mixer in MIXERS, self.mixer
        assert self.ffn in FFNS, self.ffn


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. Shapes follow the assignment table."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # --- attention ---
    head_dim: int = 0                # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0     # 0 -> no RoPE (see learned_pos)
    learned_pos: bool = False        # learned absolute positions (whisper)
    sliding_window: int = 0          # 0 -> full attention
    mlp_kind: str = "swiglu"         # swiglu | gelu

    # --- layer cycle (heterogeneous stacks) ---
    cycle: Tuple[BlockSpec, ...] = (BlockSpec("attn", "mlp"),)

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    d_ff_expert: int = 0             # expert hidden size (may differ from d_ff)
    moe_capacity_factor: float = 1.25
    router_jitter: float = 0.0

    # --- Mamba (selective SSM) ---
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0           # 0 -> ceil(d_model / 16)

    # --- xLSTM ---
    xlstm_num_heads: int = 4

    # --- encoder-decoder (whisper-style audio) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500          # stub frame-embedding count

    # --- multimodal prefix (vlm) ---
    num_prefix_tokens: int = 0       # patch embeddings occupying first slots

    # --- numerics / misc ---
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"          # parameter / activation dtype
    tie_embeddings: bool = False
    norm_kind: str = "rmsnorm"       # rmsnorm | layernorm
    kv_cache_dtype: str = "auto"     # auto (= dtype) | bfloat16 | int8
    # "auto" inherits the model dtype: a float32 model quietly caching K/V
    # in bfloat16 loses ~3 decimal digits per slot, which discrete MoE
    # routing amplifies into expert flips (decode no longer matches the
    # forward pass). int8 stays an explicit serving opt-in.
    attn_impl: str = "chunked"       # chunked (jnp flash) | naive | pallas
    remat: bool = True               # activation checkpointing over blocks
    remat_policy: str = "nothing"    # nothing | save_block_out: keep each
    # block's (seq-sharded) output so the backward pass skips the recompute
    # forward — trades ~2 x L x B x S/16 x D bytes for one whole forward's
    # FLOPs AND collectives (hillclimb lever, EXPERIMENTS.md §Perf).
    fsdp_gather_weights: bool = False  # gather FSDP weight shards on use
    # instead of computing sharded contractions (which all-reduces the much
    # larger activations). Hillclimb lever — see EXPERIMENTS.md §Perf.
    source: str = ""                 # citation for the config

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def cycle_len(self) -> int:
        return len(self.cycle)

    @property
    def num_groups(self) -> int:
        assert self.num_layers % self.cycle_len == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"cycle length {self.cycle_len}")
        return self.num_layers // self.cycle_len

    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank or -(-self.d_model // 16)

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def resolved_kv_cache_dtype(self) -> str:
        return self.dtype if self.kv_cache_dtype == "auto" \
            else self.kv_cache_dtype

    def supports_long_context(self) -> bool:
        """True if decode state is sub-quadratic in context (prompt rule for
        long_500k): recurrent mixers or bounded (sliding-window) KV."""
        has_full_attn = any(b.mixer == "attn" for b in self.cycle)
        if not has_full_attn:
            return True                      # pure SSM / xLSTM
        if self.sliding_window > 0:
            return True                      # bounded KV window
        # hybrid: a minority of full-attn layers still needs full KV, but the
        # state is dominated by the recurrent layers; jamba runs 256k context
        # in practice -> allow when attn layers are a strict minority.
        n_attn = sum(b.mixer == "attn" for b in self.cycle)
        return self.family == "hybrid" and n_attn * 2 < self.cycle_len

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in INPUT_SHAPES}


# ----------------------------------------------------------------------
# Federated-learning system config (the paper, Table I defaults)
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class FLConfig:
    """Auction-based clustered FL system parameters (paper Table I)."""

    num_clients: int = 100
    num_clusters: int = 10           # J
    select_ratio: float = 0.10       # K / N
    local_epochs: int = 1            # I
    local_momentum: float = 0.0      # client-side SGD momentum
    rounds: int = 100                # T
    lr: float = 0.05

    # clustering stage
    sample_window: int = 50          # s_mm
    cluster_resamples: int = 5       # T0
    cluster_feature_dim: int = 256   # projected gradient feature size

    # energy model
    energy_per_100_samples: float = 0.2   # rho
    energy_rx: float = 0.01               # E^re per round (receive global model)
    energy_tx: float = 0.01               # E^se per round (send local model)
    init_energy_mode: str = "full"        # full | normal  (case1 / case2)
    init_energy_mean: float = 0.75
    init_energy_std: float = 0.10
    init_energy_low: float = 0.50
    init_energy_high: float = 1.00

    # cost function (Table I)
    phi: float = 0.5        # resource-cost base, 0<phi<1
    vartheta: float = 0.5   # service-cost sample base
    chi: float = 0.7        # weight of sample term in Cs
    zeta: float = 0.3       # weight of history term in Cs (chi+zeta=1)
    log_a: float = 2.0      # log base in history term
    alpha: float = 0.7      # weight of service cost in c
    gamma: float = 0.3      # weight of resource cost in c (alpha+gamma=1)
    history_verbatim: bool = False  # eq 13 exactly as printed (see auction.py)

    # reward model
    reward_model: str = "bid_share"   # per-sample share (eq 15) | bid_share (eq 16)
    total_reward: float = 100.0       # Rg
    target_rounds: int = 100          # Nr

    # aggregation
    aggregator: str = "fedavg"        # fedavg | fedprox
    fedprox_mu: float = 0.01

    # fleet dynamics (repro.sim.dynamics) — all off by default so the
    # round-synchronous paper repro stays bit-identical; any churn or a
    # positive deadline turns the fault model on (dynamics_enabled).
    churn: float = 0.0          # per-round dropout prob (availability +
    #                             mid-round); 0 disables the churn process
    rejoin_prob: float = 0.5    # per-round arrival prob of an unavailable
    #                             client (the churn process's return edge)
    deadline: float = 0.0       # FedCS-style round deadline in units of
    #                             the fleet-mean compute+network latency;
    #                             0 = no deadline (nobody is ever late)
    straggler_profile: str = "energy"   # energy | uniform | lognormal |
    #   none — how per-client latency scale is sampled. 'energy' ties the
    #   slowdown to the residual-energy heterogeneity profile (low-energy
    #   clients are up to ~3x slower), the paper-consistent default.
    aggregation: str = "sync"   # sync | buffered. 'sync' re-weights the
    #   FedAvg over deadline survivors each round; 'buffered' additionally
    #   lands late updates in a device-resident buffer folded FedBuff-
    #   style (staleness-weighted) at goal-count or timeout boundaries.
    buffer_goal: int = 4        # fold the late buffer when this many
    #                             updates have arrived...
    buffer_timeout: int = 4     # ...or when the oldest arrived entry has
    #                             waited this many rounds, whichever first
    staleness_alpha: float = 0.5   # staleness discount exponent: a late
    #   update folded tau rounds after dispatch is scaled by
    #   (1 + tau) ** -alpha (FedBuff's 1/sqrt(1+tau) at the default)
    replace_dropped: bool = True   # retry-or-replace: resample a dropped
    #   winner's slot from its cluster's available non-winners

    @property
    def dynamics_enabled(self) -> bool:
        """True when the client-dynamics fault model is active.  The
        guard the churn-0 bit-identity regression rests on: with no
        churn and no deadline every dynamics code path is skipped and
        the round programs are the exact pre-dynamics traces."""
        return self.churn > 0.0 or self.deadline > 0.0

    # Byzantine robustness (repro.sim.dynamics corruption model +
    # repro.core.aggregation screened FedAvg) — all off by default so
    # the paper repro stays bit-identical to the pre-defense traces.
    adversary_frac: float = 0.0   # fixed fraction of the fleet that is
    #   Byzantine: round(frac * N) clients drawn once per run from the
    #   dedicated adversary PRNG chain corrupt every update they send
    attack: str = "none"          # none | nan | scale | signflip | noise
    #   | sub_clip | alie | on_off — how an adversary perturbs its param
    #   delta after local training (on device, before aggregation); the
    #   last three are ADAPTIVE attacks that observe the defense's
    #   running state (sub_clip sits just under the clip EMA threshold,
    #   alie hides inside the honest coordinate spread, on_off alternates
    #   clean/dirty phases to farm reputation); see
    #   dynamics.corrupt_updates
    attack_scale: float = 25.0    # magnitude knob: multiplier for
    #   scale/signflip, noise-std multiple of the cohort RMS for noise
    sub_clip_margin: float = 0.9  # sub_clip: the attacker targets this
    #   fraction of the STATIC clip threshold (clip_mult x clip EMA) so
    #   a fixed-threshold clip defense never touches it
    alie_z: float = 1.0           # alie: colluders move to honest mean
    #   minus z x per-coordinate honest std (small z stays inside the
    #   trimmed-mean band)
    onoff_period: int = 2         # on_off: attack for this many rounds,
    #   then behave for as many (strike decay farms reputation back)
    defense: str = "none"         # none | clip | trimmed | median —
    #   robust aggregation applied to the per-update matrix: all three
    #   non-none defenses first QUARANTINE non-finite rows (excluded
    #   from the weighted sum, survivor weights renormalized), then
    #   'clip' l2-clips each row to clip_mult x a running median norm,
    #   'trimmed'/'median' replace the weighted mean coordinate-wise
    defense_mode: str = "static"  # static | adaptive. 'static' keeps the
    #   PR-8 fixed thresholds (clip_mult, trim_frac).  'adaptive'
    #   auto-tunes the screen from device-resident running statistics:
    #   a survivor-norm median/MAD band (norms above
    #   median + k_eff x MAD are screened out and struck), where k_eff
    #   tightens as the quarantine/outlier pressure EMA rises and
    #   relaxes back as it falls — see aggregation.DefenseState
    adapt_k: float = 3.0          # adaptive screen: base MAD multiplier
    #   of the outlier band (k_eff = adapt_k / (1 + adapt_gain * press))
    adapt_gain: float = 4.0       # how hard attack pressure tightens k
    pressure_beta: float = 0.2    # EMA rate of the pressure statistic
    adapt_mad_floor: float = 0.05  # MAD floor as a fraction of the
    #   running median norm (a zero-spread cohort must not ban everyone)
    outlier_strike: float = 0.5   # reputation strikes earned per
    #   adaptive-screen exclusion (quarantine always strikes 1.0)
    clip_mult: float = 2.0        # clip threshold = clip_mult * running
    #                               median of per-update l2 norms
    clip_beta: float = 0.3        # EMA rate of that running median
    trim_frac: float = 0.3        # trimmed mean: ceil(frac * V) rows
    #                               trimmed from EACH tail per coordinate
    strike_threshold: float = 2.0  # auction reputation: a client with
    #   this many (decayed) quarantine strikes loses eligibility
    strike_decay: float = 0.98    # per-round multiplicative strike decay
    #   (banned clients eventually fall below threshold and get re-probed)
    reputation_mode: str = "ban"  # ban | price. 'ban' is the PR-8 hard
    #   gate (strikes >= strike_threshold lose auction eligibility,
    #   bit-identical traces).  'price' keeps every client eligible but
    #   multiplies the reputation penalty into the effective bid the
    #   winner ranking sees (auction.effective_bids): a tainted client
    #   must bid cheaper to win, and recovers as strikes decay
    rep_price_gain: float = 1.0   # price mode: effective bid =
    #   bid * (1 + gain * strikes); rewards still pay the TRUE bid

    # divergence watchdog (repro.core.server): ring of the last K healthy
    # snapshots + a detector on the drained eval stream (non-finite eval,
    # loss spike vs EMA, accuracy collapse); a trigger restores the
    # newest healthy snapshot, tightens the defense, decays the server
    # step scale and resumes on a perturbed key chain.  'off' (default)
    # keeps every code path and trace untouched.
    watchdog: str = "off"          # off | on
    watchdog_ring: int = 3         # snapshots kept in the rollback ring
    watchdog_loss_mult: float = 2.5  # trigger: loss > mult * loss EMA
    watchdog_acc_drop: float = 0.25  # trigger: acc < peak acc - drop
    watchdog_lr_decay: float = 0.5   # server step scale multiplier per
    #   rollback (device scalar — never retraces); 1.0 disables
    watchdog_tighten: float = 1.5    # defense tightening per rollback:
    #   the screen thresholds divide by this cumulative factor

    @property
    def adversary_enabled(self) -> bool:
        """True when corrupted-update injection is active."""
        return self.adversary_frac > 0.0 and self.attack != "none"

    @property
    def watchdog_enabled(self) -> bool:
        """True when the divergence watchdog (snapshot ring + detector +
        rollback policy) is active.  False is the guard the watchdog-off
        bit-identity regression rests on: no ring, no detector, no
        server step scale — the pre-watchdog code path runs untouched."""
        return self.watchdog == "on"

    @property
    def defended(self) -> bool:
        """True when the server must route stage-3 through the
        per-update screened-aggregation path (repro.core.aggregation)
        instead of the runtimes' fused FedAvg.  False is the guard the
        defense-off bit-identity regression rests on: with no defense
        and no adversary the pre-defense code path runs untouched."""
        return self.defense != "none" or self.adversary_enabled

    # data heterogeneity (paper §V-A)
    non_iid_level: float = 1.0        # nu: fraction of a client's data w/ one label
    imbalance_low: float = 1.0 / 6.0  # local size in [varpi/6, 2*varpi]
    imbalance_high: float = 2.0
    num_classes: int = 10

    # selection scheme under test
    scheme: str = "gradient_cluster_auction"
    # gradient_cluster_auction | gradient_cluster_random |
    # weights_cluster_random  | random

    # control-plane selection scheme (repro.core.schemes registry):
    # which per-round winner-pick program the fused round control plane
    # compiles.  'paper' routes through selection.select_round exactly
    # as before (itself dispatching on cfg.scheme above — the paper's
    # own four baselines), so the default stays bit-identical to the
    # pre-registry traces; the competitors are 'random' (uniform
    # per-cluster, availability-aware), 'fedcs' (deadline-feasibility
    # gating on predicted latency at bid time, arXiv:1804.08333) and
    # 'longterm_auction' (inter-round budget/payment state threaded as
    # SelectionState.scheme_state, arXiv:2508.09181).
    scheme_select: str = "paper"
    # fedcs: predicted-latency feasibility bound (in fleet-mean round
    # times, same units as cfg.deadline) used at bid time when
    # cfg.deadline == 0; a positive cfg.deadline takes precedence so the
    # auction gates on the same deadline the fault model enforces
    fedcs_deadline: float = 1.5

    # cohort execution backend (repro.sim): 'sequential' runs the
    # reference per-client loop; 'vectorized' runs whole cohorts as one
    # compiled vmap/scan program per size bucket; 'sharded' additionally
    # maps each bucket's client axis over the cohort mesh's 'data' axis
    # (shard_map, replicated params, psum FedAvg); 'device' keeps the
    # whole fleet's data resident on device in static capacity-class
    # tensors — per-round cohort assembly is an on-device gather and
    # nothing retraces after warm-up (see ROADMAP.md §Usage, DESIGN.md
    # §Round pipeline).
    runtime: str = "sequential"
    # evaluate test accuracy/loss every this many rounds (1 = every
    # round, the paper's cadence; the final round always evaluates,
    # skipped rounds log NaN). Evaluation results are fetched only at
    # logging boundaries, so together with the device-buffered round
    # metrics this sets the async dispatch depth of FederatedServer.run.
    eval_every: int = 1
    # devices on the cohort mesh's data axis for runtime='sharded';
    # 0 = all local devices. Degrades to the 1-device debug mesh.
    cohort_mesh_devices: int = 0
    # client-axis vmap width inside one compiled cohort program; chunks of
    # this width run under lax.map so the per-chunk working set stays
    # cache-resident on CPU (full-width vmap thrashes; measured 1.4-2x
    # slower). Must be a power of two.
    cohort_vmap_width: int = 4

    seed: int = 0

    def replace(self, **kw) -> "FLConfig":
        return dataclasses.replace(self, **kw)
