"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 — Mamba+attention 1:7 interleave, MoE every 2
layers, NO positional encoding [arXiv:2403.19887]."""
from repro.configs.base import BlockSpec, ModelConfig

# one 8-layer period: attention at index 4, MoE FFN at odd indices.
_CYCLE = tuple(
    BlockSpec("attn" if i == 4 else "mamba", "moe" if i % 2 == 1 else "mlp")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,                # 4 groups x 8-layer cycle
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    rope_theta=0.0,               # jamba: no positional encoding
    learned_pos=False,
    cycle=_CYCLE,
    num_experts=16,
    experts_per_token=2,
    d_ff_expert=14336,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    source="arXiv:2403.19887",
)


def smoke_config() -> ModelConfig:
    cycle = tuple(
        BlockSpec("attn" if i == 2 else "mamba",
                  "moe" if i % 2 == 1 else "mlp") for i in range(4))
    return CONFIG.replace(
        name="jamba-smoke", num_layers=4, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=256, d_ff_expert=256, vocab_size=256,
        num_experts=4, experts_per_token=2, cycle=cycle, dtype="float32",
        remat=False)
