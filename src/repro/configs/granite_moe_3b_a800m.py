"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) expert
d_ff=512 vocab=49155, MoE 40e top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base
family]. The assignment line says 40 experts (bracket note says 32); we
follow the explicit field."""
from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    rope_theta=1e4,
    tie_embeddings=True,
    cycle=(BlockSpec("attn", "moe"),),
    num_experts=40,
    experts_per_token=8,
    d_ff_expert=512,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="granite-moe-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=64, d_ff_expert=64, vocab_size=256,
        num_experts=4, experts_per_token=2, dtype="float32", remat=False)
