"""Architecture registry: ``--arch <id>`` -> ModelConfig.

Dashed public ids map to underscore module names. Every entry also exposes a
``smoke`` reduced variant used by the per-arch CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

_MODULES: Dict[str, str] = {
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "phi-3-vision-4.2b": "repro.configs.phi_3_vision_4_2b",
    "qwen1.5-32b": "repro.configs.qwen1_5_32b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "qwen1.5-4b": "repro.configs.qwen1_5_4b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch_id]).smoke_config()
