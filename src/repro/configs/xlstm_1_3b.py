"""xlstm-1.3b [ssm] — 48 blocks d_model=2048 4 heads d_ff=0 vocab=50304 —
sLSTM + mLSTM blocks (1:3 cycle), no FFN sublayer [arXiv:2405.04517]."""
from repro.configs.base import BlockSpec, ModelConfig

_CYCLE = (
    BlockSpec("slstm", "none"),
    BlockSpec("mlstm", "none"),
    BlockSpec("mlstm", "none"),
    BlockSpec("mlstm", "none"),
)

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    rope_theta=0.0,               # recurrent mixers need no positions
    cycle=_CYCLE,
    xlstm_num_heads=4,
    tie_embeddings=True,
    source="arXiv:2405.04517",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="xlstm-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, vocab_size=256, xlstm_num_heads=4,
        cycle=(BlockSpec("slstm", "none"), BlockSpec("mlstm", "none")),
        dtype="float32", remat=False)
