"""whisper-tiny [audio] — 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865 —
enc-dec, conv frontend STUBBED: input_specs provides precomputed
(B, 1500, 384) mel/conv frame embeddings [arXiv:2212.04356]."""
from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,                 # decoder layers
    encoder_layers=4,
    encoder_seq=1500,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    qkv_bias=True,
    rope_theta=0.0,
    learned_pos=True,             # whisper uses learned/sinusoidal positions
    mlp_kind="gelu",
    norm_kind="layernorm",
    cycle=(BlockSpec("attn", "mlp"),),
    source="arXiv:2212.04356",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="whisper-tiny-smoke", num_layers=2, encoder_layers=2,
        encoder_seq=32, d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
        vocab_size=256, dtype="float32", remat=False)
