"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152 — GQA, RoPE, sliding-window 4096 [arXiv:2402.19173]."""
from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    qkv_bias=True,
    rope_theta=1e5,
    sliding_window=4096,          # real-model property -> runs long_500k
    mlp_kind="gelu",
    norm_kind="layernorm",
    cycle=(BlockSpec("attn", "mlp"),),
    source="arXiv:2402.19173",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="starcoder2-3b-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=256, vocab_size=256, sliding_window=16,
        dtype="float32", remat=False)
