"""qwen1.5-4b [dense] — 40L d_model=2560 20H (kv=20, MHA) d_ff=6912
vocab=151936 — QKV bias [hf:Qwen/Qwen1.5-0.5B family]."""
from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1e6,
    cycle=(BlockSpec("attn", "mlp"),),
    source="hf:Qwen/Qwen1.5-0.5B",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="qwen1.5-4b-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, d_ff=256, vocab_size=256, dtype="float32",
        remat=False)
