"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064 — phi3-mini backbone + CLIP vision encoder STUBBED:
input_specs provides (B, 256, 3072) projected patch embeddings occupying
the first 256 token slots [hf:microsoft/Phi-3-vision-128k-instruct]."""
from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=1e4,
    num_prefix_tokens=256,
    cycle=(BlockSpec("attn", "mlp"),),
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="phi3v-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, d_ff=256, vocab_size=256, num_prefix_tokens=8,
        dtype="float32", remat=False)
