"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B family].

Note: head_dim is taken as d_model // num_heads = 64 per the exact assigned
config (the HF card uses 128; we follow the assignment table)."""
from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    rope_theta=1e6,
    cycle=(BlockSpec("attn", "moe"),),
    num_experts=128,
    experts_per_token=8,
    d_ff_expert=1536,
    source="hf:Qwen/Qwen3-30B-A3B",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="qwen3-moe-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=64, d_ff_expert=64, vocab_size=256,
        num_experts=4, experts_per_token=2, dtype="float32", remat=False)
