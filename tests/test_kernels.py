"""Per-kernel allclose tests: shape/dtype sweeps against the ref.py oracles,
executed in Pallas interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.kmeans import kmeans_assign, lloyd_step
from repro.kernels.flash_attention import flash_attention


KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("n,f,k", [
    (16, 8, 2), (100, 64, 10), (257, 256, 7), (512, 100, 16), (33, 33, 3),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_assign_matches_ref(n, f, k, dtype):
    kx, kc = jax.random.split(jax.random.fold_in(KEY, n * f + k))
    x = jax.random.normal(kx, (n, f), dtype=dtype)
    c = jax.random.normal(kc, (k, f), dtype=dtype)
    lab, dist = kmeans_assign(x, c, interpret=True)
    lab_ref = ref.kmeans_assign_ref(x, c)
    dist_ref = ref.kmeans_min_dist_ref(x, c)
    # bf16 rounding can flip near-ties; require distance-consistency instead
    # of exact label match in that case.
    if dtype == jnp.float32:
        np.testing.assert_array_equal(np.asarray(lab), np.asarray(lab_ref))
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(dist), np.asarray(dist_ref),
                               rtol=tol, atol=tol)


def test_kmeans_assign_backend_probe_default():
    """interpret=None (the default) probes the backend — off-TPU it must
    resolve to interpret mode and agree with the oracle, so call sites no
    longer hard-code interpret=True."""
    kx, kc = jax.random.split(KEY)
    x = jax.random.normal(kx, (130, 48))
    c = jax.random.normal(kc, (5, 48))
    lab, dist = kmeans_assign(x, c)          # no interpret argument
    np.testing.assert_array_equal(np.asarray(lab),
                                  np.asarray(ref.kmeans_assign_ref(x, c)))
    np.testing.assert_allclose(np.asarray(dist),
                               np.asarray(ref.kmeans_min_dist_ref(x, c)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("impl", ["auto", "pallas", "ref"])
def test_ops_kmeans_assign_impls_agree(impl):
    kx, kc = jax.random.split(jax.random.fold_in(KEY, 3))
    x = jax.random.normal(kx, (200, 32))
    c = jax.random.normal(kc, (6, 32))
    np.testing.assert_array_equal(
        np.asarray(ops.kmeans_assign(x, c, impl=impl)),
        np.asarray(ref.kmeans_assign_ref(x, c)))


@pytest.mark.parametrize("n,f,k", [
    # unpadded (multiples of the 128-lane tiles) and padded N, F and K
    (256, 128, 8), (16, 8, 2), (100, 64, 10), (257, 256, 7), (130, 100, 16),
    (33, 33, 3),
])
def test_lloyd_step_matches_ref(n, f, k):
    """Fused assign+update kernel: labels, min-distances, per-centroid
    partial sums and counts all match the oracle (padded rows masked)."""
    kx, kc = jax.random.split(jax.random.fold_in(KEY, n * f + k))
    x = jax.random.normal(kx, (n, f))
    c = jax.random.normal(kc, (k, f))
    lab, dist, sums, counts = lloyd_step(x, c, interpret=True)
    lab_r, dist_r, sums_r, counts_r = ref.lloyd_step_ref(x, c)
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(lab_r))
    np.testing.assert_allclose(np.asarray(dist), np.asarray(dist_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(sums_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(counts_r))
    assert int(counts.sum()) == n            # padding contributes nothing


@pytest.mark.parametrize("impl", ["auto", "pallas", "ref"])
def test_ops_lloyd_step_impls_agree(impl):
    kx, kc = jax.random.split(jax.random.fold_in(KEY, 11))
    x = jax.random.normal(kx, (150, 40))
    c = jax.random.normal(kc, (5, 40))
    lab, dist, sums, counts = ops.lloyd_step(x, c, impl=impl)
    lab_r, dist_r, sums_r, counts_r = ref.lloyd_step_ref(x, c)
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(lab_r))
    np.testing.assert_allclose(np.asarray(dist), np.asarray(dist_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(sums_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(counts_r))


@pytest.mark.parametrize("b,s,h,hd", [
    (1, 64, 1, 16), (2, 128, 4, 64), (1, 200, 2, 32), (2, 96, 3, 8),
])
@pytest.mark.parametrize("causal,window", [
    (True, 0), (False, 0), (True, 32),
])
def test_flash_attention_matches_ref(b, s, h, hd, causal, window):
    ks = jax.random.split(jax.random.fold_in(KEY, s * h), 3)
    q, k, v = (jax.random.normal(kk, (b, s, h, hd), dtype=jnp.float32)
               for kk in ks)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16(dtype):
    ks = jax.random.split(KEY, 3)
    q, k, v = (jax.random.normal(kk, (2, 128, 2, 32), dtype=dtype)
               for kk in ks)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=3e-2, atol=3e-2)


def test_jnp_flash_vjp_matches_naive_autodiff():
    """The custom VJP of the model-side jnp flash attention must match
    autodiff through the naive implementation."""
    from repro.models.layers import chunked_attention, naive_attention
    ks = jax.random.split(KEY, 3)
    q, k, v = (jax.random.normal(kk, (2, 100, 3, 32)) for kk in ks)

    def f(q, k, v):
        return (chunked_attention(q, k, v, causal=True, window=0,
                                  q_block=32, kv_block=48) ** 2).sum()

    def g(q, k, v):
        return (naive_attention(q, k, v, causal=True, window=0) ** 2).sum()

    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_kmeans_inside_lloyd_converges():
    """Pallas assignment inside Lloyd's recovers 4 well-separated blobs
    (interpret selected by the backend probe, not hard-coded)."""
    from repro.core.clustering import kmeans
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(4, 16)) * 10
    pts = np.concatenate([c + rng.normal(size=(50, 16)) for c in centers])
    labels, cent = kmeans(
        jnp.asarray(pts, jnp.float32), 4, jax.random.PRNGKey(0),
        assign_fn=lambda x, c: kmeans_assign(x, c)[0])
    lab = np.asarray(labels).reshape(4, 50)
    for g in range(4):
        assert len(np.unique(lab[g])) == 1   # each blob in one cluster
    assert len(np.unique(lab[:, 0])) == 4    # blobs in distinct clusters
