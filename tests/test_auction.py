"""Property tests (hypothesis) for the auction mechanism — Theorem 2's Nash
bid, the cost function, winner selection and reward models."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need the optional hypothesis extra")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs.base import FLConfig
from repro.core import auction as A
from repro.core import energy as E

CFG = FLConfig()

finite_cost = st.floats(0.0, 1.0)
nj_kj = st.tuples(st.integers(2, 50), st.integers(1, 10)).filter(
    lambda t: t[1] < t[0])


@given(c=finite_cost, njkj=nj_kj)
@settings(max_examples=200, deadline=None)
def test_optimal_bid_bounds(c, njkj):
    """b* in [c, 1] for c in [0,1]: the Nash bid never bids below cost and
    never above the max valuation 1."""
    nj, kj = njkj
    b = float(A.optimal_bid(jnp.float32(c), nj, kj))
    assert b >= c - 1e-6
    assert b <= 1.0 + 1e-6


@given(c1=finite_cost, c2=finite_cost, njkj=nj_kj)
@settings(max_examples=200, deadline=None)
def test_optimal_bid_monotone_in_cost(c1, c2, njkj):
    """The equilibrium bid strategy is strictly increasing in cost
    (condition ii of the auction model)."""
    nj, kj = njkj
    b1 = float(A.optimal_bid(jnp.float32(c1), nj, kj))
    b2 = float(A.optimal_bid(jnp.float32(c2), nj, kj))
    if c1 < c2:
        assert b1 <= b2 + 1e-7


@given(c=finite_cost, njkj=nj_kj)
@settings(max_examples=100, deadline=None)
def test_equilibrium_revenue_nonnegative(c, njkj):
    """U_i = b - c >= 0 at the Nash bid (rationality)."""
    nj, kj = njkj
    b = A.optimal_bid(jnp.float32(c), nj, kj)
    u = float(A.revenue(b, jnp.float32(c), jnp.bool_(True)))
    assert u >= -1e-6


@given(njkj=nj_kj)
@settings(max_examples=50, deadline=None)
def test_more_competition_lowers_bids(njkj):
    """As N_j grows with K_j fixed, the bid premium 1/(N_j-K_j+1) shrinks:
    more bidders -> more competitive bids."""
    nj, kj = njkj
    c = jnp.float32(0.4)
    b_small = float(A.optimal_bid(c, nj, kj))
    b_big = float(A.optimal_bid(c, nj + 10, kj))
    assert b_big <= b_small + 1e-7


@given(res=st.floats(1.0, 100.0), res2=st.floats(1.0, 100.0),
       size=st.integers(10, 1200))
@settings(max_examples=100, deadline=None)
def test_resource_cost_monotone_in_residual(res, res2, size):
    """Cr rises as the battery drains (eq 12)."""
    e_cp = E.compute_cost_energy(jnp.int32(size), CFG)
    c1 = float(A.resource_cost(jnp.float32(res), e_cp, CFG))
    c2 = float(A.resource_cost(jnp.float32(res2), e_cp, CFG))
    if res < res2 and c1 < A.INF and c2 < A.INF:
        assert c1 >= c2 - 1e-9


def test_resource_cost_infinite_when_depleted():
    e_cp = E.compute_cost_energy(jnp.int32(600), CFG)  # 1.2%
    assert float(A.resource_cost(jnp.float32(1.0), e_cp, CFG)) >= 1e8
    assert float(A.resource_cost(jnp.float32(50.0), e_cp, CFG)) < 1.0


@given(ns1=st.integers(1, 1200), ns2=st.integers(1, 1200),
       co=st.integers(0, 100))
@settings(max_examples=100, deadline=None)
def test_service_cost_decreases_with_samples(ns1, ns2, co):
    """Clients with more samples have lower service cost (eq 13)."""
    c1 = float(A.service_cost(jnp.int32(ns1), jnp.int32(co), CFG))
    c2 = float(A.service_cost(jnp.int32(ns2), jnp.int32(co), CFG))
    if ns1 < ns2:
        assert c1 >= c2 - 1e-9


@given(seed=st.integers(0, 1000), n=st.integers(5, 60), k=st.integers(1, 5))
@settings(max_examples=50, deadline=None)
def test_winners_are_lowest_bids(seed, n, k):
    rng = np.random.default_rng(seed)
    bids = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
    eligible = jnp.asarray(rng.uniform(0, 1, n) > 0.3)
    win = A.select_lowest_bids(bids, eligible, k)
    w = np.asarray(win)
    el = np.asarray(eligible)
    assert w.sum() <= k
    assert not np.any(w & ~el)
    if w.any() and (el & ~w).any():
        assert np.asarray(bids)[w].max() <= np.asarray(bids)[el & ~w].min() + 1e-6


@given(seed=st.integers(0, 500))
@settings(max_examples=30, deadline=None)
def test_cluster_winners_per_cluster_cap(seed):
    rng = np.random.default_rng(seed)
    n, j, kj = 60, 5, 2
    bids = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
    clusters = jnp.asarray(rng.integers(0, j, n), jnp.int32)
    eligible = jnp.ones((n,), bool)
    win = np.asarray(A.cluster_winners(bids, clusters, eligible, kj, j))
    cl = np.asarray(clusters)
    for c in range(j):
        assert win[cl == c].sum() <= kj


@given(seed=st.integers(0, 500))
@settings(max_examples=30, deadline=None)
def test_reward_conservation(seed):
    """eq 15: winners' rewards sum to exactly Rg/Nr; eq 16: client + server
    shares never exceed Rg/Nr per winner."""
    rng = np.random.default_rng(seed)
    n = 40
    won = jnp.asarray(rng.uniform(0, 1, n) > 0.7)
    sizes = jnp.asarray(rng.integers(100, 1200, n), jnp.int32)
    bids = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
    per_round = CFG.total_reward / CFG.target_rounds
    r15 = A.reward_sample_share(won, sizes, CFG)
    if bool(won.any()):
        np.testing.assert_allclose(float(r15.sum()), per_round, rtol=1e-5)
    assert not np.any(np.asarray(r15)[~np.asarray(won)] > 0)
    r16, server = A.reward_bid_share(won, bids, CFG)
    assert np.all(np.asarray(r16) <= per_round + 1e-6)
    assert not np.any(np.asarray(r16)[~np.asarray(won)] > 0)
