"""Substrate tests: data partitioning (hypothesis), optimizers, checkpoint
round-trips, losses, energy model."""
import os
import tempfile

import pytest

pytest.importorskip("hypothesis", reason="property tests need the optional hypothesis extra")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs.base import FLConfig
from repro.core import energy as EN
from repro.data.partition import (client_label_histograms, global_histogram,
                                  partition_clients)
from repro.data.synthetic import make_image_dataset, make_token_dataset
from repro.optim import adamw, apply_updates, fedprox_grad, sgd


# ----------------------------- partition ------------------------------

@given(nu=st.sampled_from([1.0, 0.8, 0.5]), n_clients=st.integers(5, 40),
       seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_partition_invariants(nu, n_clients, seed):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 10, 4000).astype(np.int32)
    cfg = FLConfig(num_clients=n_clients, non_iid_level=nu)
    clients = partition_clients(y, cfg, seed=seed)
    assert len(clients) == n_clients
    varpi = 4000 // n_clients
    for c in clients:
        total = len(c.train_idx) + len(c.val_idx) + len(c.test_idx)
        # local size within [varpi/6, 2*varpi] (allowing the floor of 10)
        assert total >= max(varpi // 6, 10) - 1
        assert total <= 2 * varpi + 1
        # 80/10/10 split
        assert abs(len(c.train_idx) - 0.8 * total) <= 2
        # non-IID level: fraction of primary label ~ nu
        lab = y[np.concatenate([c.train_idx, c.val_idx, c.test_idx])]
        frac = (lab == c.primary_label).mean()
        assert frac >= nu - 0.15


def test_partition_histograms():
    rng = np.random.default_rng(0)
    y = rng.integers(0, 10, 5000).astype(np.int32)
    cfg = FLConfig(num_clients=20, non_iid_level=1.0)
    clients = partition_clients(y, cfg)
    h = client_label_histograms(y, clients, 10)
    # at nu=1 every client's histogram is (approximately) one-hot
    assert (h.max(axis=1) > 0.95).all()
    g = global_histogram(y, 10)
    np.testing.assert_allclose(g.sum(), 1.0)


def test_synthetic_datasets():
    tr, te = make_image_dataset("mnist", n_train=500, n_test=100)
    assert tr.x.shape == (500, 28, 28, 1) and te.y.shape == (100,)
    assert tr.x.min() >= 0 and tr.x.max() <= 1
    tr2, _ = make_image_dataset("cifar", n_train=200, n_test=50)
    assert tr2.x.shape == (200, 32, 32, 3)
    toks, topics = make_token_dataset(n=100, vocab=64, seq_len=16)
    assert toks.shape == (100, 16) and toks.max() < 64


# ----------------------------- optimizers -----------------------------

def _quad_loss(p):
    return ((p["w"] - 3.0) ** 2).sum() + ((p["b"] + 1.0) ** 2).sum()


@pytest.mark.parametrize("maker", [
    lambda: sgd(0.1), lambda: sgd(0.05, momentum=0.9), lambda: adamw(0.1)])
def test_optimizers_minimize_quadratic(maker):
    init, upd = maker()
    p = {"w": jnp.zeros((3,)), "b": jnp.zeros((2,))}
    s = init(p)
    for _ in range(200):
        g = jax.grad(_quad_loss)(p)
        u, s = upd(g, s, p)
        p = apply_updates(p, u)
    assert _quad_loss(p) < 1e-3


def test_fedprox_pulls_toward_global():
    p = {"w": jnp.ones((4,)) * 5.0}
    glob = {"w": jnp.zeros((4,))}
    g = {"w": jnp.zeros((4,))}
    g2 = fedprox_grad(g, p, glob, mu=0.1)
    np.testing.assert_allclose(np.asarray(g2["w"]), 0.5)   # mu*(w - w_t)


# ----------------------------- checkpoint -----------------------------

def test_checkpoint_roundtrip():
    from repro.checkpoint.io import restore, save
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "t": (jnp.zeros((2,)), jnp.ones((1,), jnp.int32))}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt")
        save(path, tree, step=7)
        got, step = restore(path, tree)
        assert step == 7
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


# ----------------------------- losses ---------------------------------

@given(b=st.integers(1, 3), s=st.integers(3, 40), v=st.integers(5, 50),
       chunk=st.integers(2, 16))
@settings(max_examples=25, deadline=None)
def test_chunked_xent_matches_direct(b, s, v, chunk):
    from repro.models.layers import chunked_softmax_xent
    key = jax.random.PRNGKey(b * s + v)
    ks = jax.random.split(key, 4)
    d = 8
    x = jax.random.normal(ks[0], (b, s, d))
    w = jax.random.normal(ks[1], (d, v))
    labels = jax.random.randint(ks[2], (b, s), 0, v)
    mask = (jax.random.uniform(ks[3], (b, s)) > 0.3).astype(jnp.float32)
    got = chunked_softmax_xent(None, x, w, labels, mask, chunk=chunk)
    logits = x @ w
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
    expect = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    np.testing.assert_allclose(float(got), float(expect), rtol=2e-5,
                               atol=1e-5)


# ----------------------------- energy ---------------------------------

def test_energy_model():
    cfg = FLConfig(num_clients=10)
    e = EN.init_energy(cfg, jax.random.PRNGKey(0))
    assert e.shape == (10,) and float(e.min()) == 100.0
    cfg2 = cfg.replace(init_energy_mode="normal")
    e2 = EN.init_energy(cfg2, jax.random.PRNGKey(0))
    assert float(e2.min()) >= 50.0 and float(e2.max()) <= 100.0
    sizes = jnp.full((10,), 600, jnp.int32)
    sel = jnp.zeros((10,), bool).at[0].set(True)
    out = EN.apply_round(e, sel, sizes, cfg)
    assert float(out[0]) < 100.0 and float(out[1]) == 100.0
    # floors at zero
    tiny = jnp.full((10,), 0.5, jnp.float32)
    out2 = EN.apply_round(tiny, jnp.ones((10,), bool), sizes, cfg)
    assert float(out2.min()) == 0.0
