"""Cohort execution engine (repro.sim): packing invariants and
sequential-vs-vectorized equivalence across schemes and uneven shards."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core.adapters import cnn_adapter
from repro.core.server import FederatedServer
from repro.data.partition import partition_clients
from repro.data.synthetic import make_image_dataset
from repro.sim.cohort import (oracle_batch_plan, pack_cohort,
                              sequential_batch_plan)
from repro.sim.runtime import make_runtime

# small pool + strong imbalance: some clients hold fewer than 32 train
# samples, so packing produces several batch-size buckets and clients
# with unequal step counts (exercising the padding masks)
N_CLIENTS = 10
POOL = 700


def _cfg(**kw):
    base = dict(num_clients=N_CLIENTS, num_clusters=3, select_ratio=0.4,
                rounds=2, local_epochs=2, sample_window=10,
                cluster_resamples=2, init_energy_mode="normal", seed=3)
    base.update(kw)
    return FLConfig(**base)


@pytest.fixture(scope="module")
def data():
    train, test = make_image_dataset("mnist", n_train=POOL, n_test=120,
                                     seed=3)
    return train, test


def _server(cfg, data):
    train, test = data
    clients = partition_clients(train.y, cfg, seed=3)
    return FederatedServer(cfg, cnn_adapter("mnist"), train.x, train.y,
                           clients, {"x": test.x[:64], "y": test.y[:64]})


# ----------------------------------------------------------------------
# packing invariants
# ----------------------------------------------------------------------

def test_oracle_batch_plan_matches_loop():
    rng = np.random.default_rng(7)
    plan = oracle_batch_plan(100, 32, 2, rng)
    rng2 = np.random.default_rng(7)
    rows = []
    for _ in range(2):
        order = rng2.permutation(100)
        for i in range(0, 100 - 32 + 1, 32):
            rows.append(order[i:i + 32])
    assert (plan == np.stack(rows)).all()
    assert plan.shape == (6, 32)          # 3 full batches per epoch


def test_sequential_plan_drops_remainder():
    plan = sequential_batch_plan(70, 32)
    assert plan.shape == (2, 32)
    assert (plan == np.arange(64).reshape(2, 32)).all()


def test_pack_cohort_masks_and_weights(data):
    cfg = _cfg()
    train, _ = data
    clients = partition_clients(train.y, cfg, seed=3)
    sel = np.arange(N_CLIENTS)
    hist = np.zeros(N_CLIENTS, np.int64)
    buckets = pack_cohort(train.x, train.y, clients, sel, hist, cfg)
    sizes = np.array([c.size for c in clients], np.float64)
    pk = sizes / sizes.sum()
    seen = {}
    for b in buckets:
        assert b.step_mask.shape == b.xb.shape[:2] == b.yb.shape[:2]
        assert b.xb.shape[2] == b.batch_size
        for row, cid in enumerate(b.client_idx):
            if cid < 0:                        # padding row: fully masked
                assert b.step_mask[row].sum() == 0
                assert b.weights[row] == 0
            else:
                n = clients[cid].size
                bs = min(32, n)
                steps = (n - bs) // bs + 1
                assert b.batch_size == bs
                assert b.step_mask[row].sum() == steps * cfg.local_epochs
                assert b.weights[row] == pytest.approx(pk[cid])
                seen[int(cid)] = seen.get(int(cid), 0) + 1
    assert sorted(seen) == list(range(N_CLIENTS))   # each client once
    total_w = sum(float(b.weights.sum()) for b in buckets)
    assert total_w == pytest.approx(1.0)
    assert len(buckets) > 1       # uneven shards -> several buckets


# ----------------------------------------------------------------------
# CNN hot-path rewrite oracles (im2col conv / reshape maxpool — the
# engine's vmap path depends on these formulations, see DESIGN.md)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("padding,cin,cout", [
    ("VALID", 1, 10), ("VALID", 3, 6), ("SAME", 1, 16), ("SAME", 16, 32),
])
def test_conv2d_im2col_matches_lax(padding, cin, cout):
    from repro.models.cnn import conv2d, conv2d_lax
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 14, 14, cin))
    w = jax.random.normal(jax.random.fold_in(key, 1), (5, 5, cin, cout))
    b = jax.random.normal(jax.random.fold_in(key, 2), (cout,))
    got = conv2d(x, w, b, padding)
    ref = conv2d_lax(x, w, b, padding)
    assert got.shape == ref.shape
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-4


@pytest.mark.parametrize("h,w", [(24, 24), (7, 7), (14, 10)])
def test_maxpool2_matches_reduce_window(h, w):
    from jax import lax
    from repro.models.cnn import maxpool2
    x = jax.random.normal(jax.random.PRNGKey(1), (3, h, w, 5))
    ref = lax.reduce_window(x, -jnp.inf, lax.max, (1, 2, 2, 1),
                            (1, 2, 2, 1), "VALID")
    assert (maxpool2(x) == ref).all()


# ----------------------------------------------------------------------
# engine vs oracle equivalence
# ----------------------------------------------------------------------

def _max_param_diff(p1, p2) -> float:
    return max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)))


def test_train_cohort_matches_oracle(data):
    """One cohort, every client, nonzero histories: aggregated params of
    the two backends agree up to float reassociation."""
    cfg = _cfg()
    train, _ = data
    clients = partition_clients(train.y, cfg, seed=3)
    adapter = cnn_adapter("mnist")
    params = adapter.init(jax.random.PRNGKey(0))
    hist = np.arange(N_CLIENTS) % 3
    sel = np.arange(N_CLIENTS)
    seq = make_runtime(cfg.replace(runtime="sequential"), adapter,
                       train.x, train.y, clients)
    vec = make_runtime(cfg.replace(runtime="vectorized"), adapter,
                       train.x, train.y, clients)
    p_seq = seq.train_cohort(params, sel, hist)
    p_vec = vec.train_cohort(params, sel, hist)
    assert _max_param_diff(p_seq, p_vec) < 1e-4


def test_train_cohort_empty_is_noop(data):
    cfg = _cfg(runtime="vectorized")
    train, _ = data
    clients = partition_clients(train.y, cfg, seed=3)
    adapter = cnn_adapter("mnist")
    params = adapter.init(jax.random.PRNGKey(0))
    rt = make_runtime(cfg, adapter, train.x, train.y, clients)
    assert rt.train_cohort(params, np.array([], np.int64),
                           np.zeros(N_CLIENTS)) is None


@pytest.mark.parametrize("scheme,aggregator", [
    ("random", "fedavg"),
    ("gradient_cluster_auction", "fedavg"),
    ("gradient_cluster_auction", "fedprox"),
])
def test_full_loop_equivalence(data, scheme, aggregator):
    """Both runtimes produce identical RoundLog selection/energy fields
    and matching aggregated params over full rounds (clustering included
    for the auction scheme — the vectorized gradient-feature pass must
    reproduce the reference clustering exactly)."""
    logs, params = {}, {}
    for runtime in ("sequential", "vectorized"):
        srv = _server(_cfg(scheme=scheme, aggregator=aggregator,
                           runtime=runtime), data)
        logs[runtime] = srv.run()
        params[runtime] = srv.params
    for l_seq, l_vec in zip(logs["sequential"], logs["vectorized"]):
        assert (l_seq.selected == l_vec.selected).all()
        assert l_seq.energy_std == l_vec.energy_std
        assert l_seq.mean_bid == l_vec.mean_bid
        assert l_seq.server_reward == l_vec.server_reward
    assert _max_param_diff(params["sequential"],
                           params["vectorized"]) < 1e-4
