"""Cohort execution engine (repro.sim): packing invariants and
sequential-vs-{vectorized,sharded} equivalence across schemes and uneven
shards.  In this process the sharded runtime runs on the 1-device debug
mesh (same shard_map program, data axis size 1); the forced-8-device CPU
mesh is exercised by the subprocess test at the bottom (XLA_FLAGS must be
set before first jax init — see launch/mesh.py)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core.adapters import cnn_adapter
from repro.core.server import FederatedServer
from repro.data.partition import ClientData, partition_clients
from repro.data.synthetic import make_image_dataset
from repro.sim.cohort import (oracle_batch_plan, pack_cohort,
                              sequential_batch_plan)
from repro.sim.runtime import make_runtime

ENGINE_RUNTIMES = ("vectorized", "sharded", "device")

# small pool + strong imbalance: some clients hold fewer than 32 train
# samples, so packing produces several batch-size buckets and clients
# with unequal step counts (exercising the padding masks)
N_CLIENTS = 10
POOL = 700


def _cfg(**kw):
    base = dict(num_clients=N_CLIENTS, num_clusters=3, select_ratio=0.4,
                rounds=2, local_epochs=2, sample_window=10,
                cluster_resamples=2, init_energy_mode="normal", seed=3)
    base.update(kw)
    return FLConfig(**base)


@pytest.fixture(scope="module")
def data():
    train, test = make_image_dataset("mnist", n_train=POOL, n_test=120,
                                     seed=3)
    return train, test


def _server(cfg, data):
    train, test = data
    clients = partition_clients(train.y, cfg, seed=3)
    return FederatedServer(cfg, cnn_adapter("mnist"), train.x, train.y,
                           clients, {"x": test.x[:64], "y": test.y[:64]})


# ----------------------------------------------------------------------
# packing invariants
# ----------------------------------------------------------------------

def test_oracle_batch_plan_matches_loop():
    rng = np.random.default_rng(7)
    plan = oracle_batch_plan(100, 32, 2, rng)
    rng2 = np.random.default_rng(7)
    rows = []
    for _ in range(2):
        order = rng2.permutation(100)
        for i in range(0, 100 - 32 + 1, 32):
            rows.append(order[i:i + 32])
    assert (plan == np.stack(rows)).all()
    assert plan.shape == (6, 32)          # 3 full batches per epoch


def test_sequential_plan_drops_remainder():
    plan = sequential_batch_plan(70, 32)
    assert plan.shape == (2, 32)
    assert (plan == np.arange(64).reshape(2, 32)).all()


def test_pack_cohort_masks_and_weights(data):
    cfg = _cfg()
    train, _ = data
    clients = partition_clients(train.y, cfg, seed=3)
    sel = np.arange(N_CLIENTS)
    hist = np.zeros(N_CLIENTS, np.int64)
    buckets = pack_cohort(train.x, train.y, clients, sel, hist, cfg)
    sizes = np.array([c.size for c in clients], np.float64)
    pk = sizes / sizes.sum()
    seen = {}
    for b in buckets:
        assert b.step_mask.shape == b.xb.shape[:2] == b.yb.shape[:2]
        assert b.xb.shape[2] == b.batch_size
        for row, cid in enumerate(b.client_idx):
            if cid < 0:                        # padding row: fully masked
                assert b.step_mask[row].sum() == 0
                assert b.weights[row] == 0
            else:
                n = clients[cid].size
                bs = min(32, n)
                steps = (n - bs) // bs + 1
                assert b.batch_size == bs
                assert b.step_mask[row].sum() == steps * cfg.local_epochs
                assert b.weights[row] == pytest.approx(pk[cid])
                seen[int(cid)] = seen.get(int(cid), 0) + 1
    assert sorted(seen) == list(range(N_CLIENTS))   # each client once
    total_w = sum(float(b.weights.sum()) for b in buckets)
    assert total_w == pytest.approx(1.0)
    assert len(buckets) > 1       # uneven shards -> several buckets


# ----------------------------------------------------------------------
# CNN hot-path rewrite oracles (im2col conv / reshape maxpool — the
# engine's vmap path depends on these formulations, see DESIGN.md)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("padding,cin,cout", [
    ("VALID", 1, 10), ("VALID", 3, 6), ("SAME", 1, 16), ("SAME", 16, 32),
])
def test_conv2d_im2col_matches_lax(padding, cin, cout):
    from repro.models.cnn import conv2d, conv2d_lax
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 14, 14, cin))
    w = jax.random.normal(jax.random.fold_in(key, 1), (5, 5, cin, cout))
    b = jax.random.normal(jax.random.fold_in(key, 2), (cout,))
    got = conv2d(x, w, b, padding)
    ref = conv2d_lax(x, w, b, padding)
    assert got.shape == ref.shape
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-4


@pytest.mark.parametrize("h,w", [(24, 24), (7, 7), (14, 10)])
def test_maxpool2_matches_reduce_window(h, w):
    from jax import lax
    from repro.models.cnn import maxpool2
    x = jax.random.normal(jax.random.PRNGKey(1), (3, h, w, 5))
    ref = lax.reduce_window(x, -jnp.inf, lax.max, (1, 2, 2, 1),
                            (1, 2, 2, 1), "VALID")
    assert (maxpool2(x) == ref).all()


# ----------------------------------------------------------------------
# engine vs oracle equivalence
# ----------------------------------------------------------------------

def _max_param_diff(p1, p2) -> float:
    return max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)))


@pytest.mark.parametrize("runtime", ENGINE_RUNTIMES)
def test_train_cohort_matches_oracle(data, runtime):
    """One cohort, every client, nonzero histories: aggregated params of
    the engine backends agree with the oracle up to float reassociation
    (sharded runs on the 1-device debug mesh here)."""
    cfg = _cfg()
    train, _ = data
    clients = partition_clients(train.y, cfg, seed=3)
    adapter = cnn_adapter("mnist")
    params = adapter.init(jax.random.PRNGKey(0))
    hist = np.arange(N_CLIENTS) % 3
    sel = np.arange(N_CLIENTS)
    seq = make_runtime(cfg.replace(runtime="sequential"), adapter,
                       train.x, train.y, clients)
    eng = make_runtime(cfg.replace(runtime=runtime), adapter,
                       train.x, train.y, clients)
    p_seq = seq.train_cohort(params, sel, hist)
    p_eng = eng.train_cohort(params, sel, hist)
    assert _max_param_diff(p_seq, p_eng) < 1e-4


@pytest.mark.parametrize("runtime", ENGINE_RUNTIMES)
def test_train_cohort_empty_is_noop(data, runtime):
    cfg = _cfg(runtime=runtime)
    train, _ = data
    clients = partition_clients(train.y, cfg, seed=3)
    adapter = cnn_adapter("mnist")
    params = adapter.init(jax.random.PRNGKey(0))
    rt = make_runtime(cfg, adapter, train.x, train.y, clients)
    assert rt.train_cohort(params, np.array([], np.int64),
                           np.zeros(N_CLIENTS)) is None


def _zero_size_client() -> ClientData:
    e = np.empty((0,), np.int64)
    return ClientData(train_idx=e, val_idx=e, test_idx=e, primary_label=0)


@pytest.mark.parametrize("runtime",
                         ("sequential",) + ENGINE_RUNTIMES)
def test_all_zero_size_cohort_skips_aggregation(data, runtime):
    """Winners with no local samples must not zero the global params: an
    all-zero cohort returns None (the old sequential path multiplied the
    params by an all-zero ``pk`` vector)."""
    cfg = _cfg(runtime=runtime)
    train, _ = data
    clients = [_zero_size_client() for _ in range(3)]
    adapter = cnn_adapter("mnist")
    params = adapter.init(jax.random.PRNGKey(0))
    rt = make_runtime(cfg, adapter, train.x, train.y, clients)
    assert rt.train_cohort(params, np.arange(3), np.zeros(3)) is None


@pytest.mark.parametrize("runtime", ENGINE_RUNTIMES)
def test_zero_size_winner_dropped_from_cohort(data, runtime):
    """A zero-size winner among real ones is dropped; the remaining
    cohort matches the oracle on the same reduced selection."""
    cfg = _cfg()
    train, _ = data
    clients = (list(partition_clients(train.y, cfg, seed=3))[:4]
               + [_zero_size_client()])
    adapter = cnn_adapter("mnist")
    params = adapter.init(jax.random.PRNGKey(0))
    hist = np.zeros(5, np.int64)
    seq = make_runtime(cfg.replace(runtime="sequential"), adapter,
                       train.x, train.y, clients)
    eng = make_runtime(cfg.replace(runtime=runtime), adapter,
                       train.x, train.y, clients)
    p_seq = seq.train_cohort(params, np.arange(5), hist)   # drops idx 4
    p_ref = seq.train_cohort(params, np.arange(4), hist)
    p_eng = eng.train_cohort(params, np.arange(5), hist)
    assert _max_param_diff(p_seq, p_ref) == 0.0
    assert _max_param_diff(p_seq, p_eng) < 1e-4


def test_weight_features_missing_client_raises(data):
    """A client id never placed in any bucket must fail loudly (the old
    path died inside jnp.stack with an opaque TypeError)."""
    cfg = _cfg(runtime="vectorized")
    train, _ = data
    clients = partition_clients(train.y, cfg, seed=3)
    adapter = cnn_adapter("mnist")
    params = adapter.init(jax.random.PRNGKey(0))
    rt = make_runtime(cfg, adapter, train.x, train.y, clients)
    from repro.sim.cohort import pack_feature_pass
    buckets = pack_feature_pass(train.x, train.y, clients,
                                chunk_width=cfg.cohort_vmap_width)
    with pytest.raises(ValueError, match="missing from the packed buckets"):
        # claim one more client than was packed -> id N has no row
        rt.engine.weight_features(params, buckets, len(clients) + 1)


@pytest.mark.parametrize("scheme,aggregator,runtime", [
    ("random", "fedavg", "vectorized"),
    ("gradient_cluster_auction", "fedavg", "vectorized"),
    ("gradient_cluster_auction", "fedprox", "vectorized"),
    ("gradient_cluster_auction", "fedavg", "sharded"),
    ("random", "fedavg", "device"),
    ("gradient_cluster_auction", "fedavg", "device"),
    ("gradient_cluster_auction", "fedprox", "device"),
])
def test_full_loop_equivalence(data, scheme, aggregator, runtime):
    """Engine runtimes produce identical RoundLog selection/energy fields
    and matching aggregated params over full rounds (clustering included
    for the auction scheme — the engine gradient-feature pass must
    reproduce the reference clustering exactly)."""
    logs, params = {}, {}
    for rt in ("sequential", runtime):
        srv = _server(_cfg(scheme=scheme, aggregator=aggregator,
                           runtime=rt), data)
        logs[rt] = srv.run()
        params[rt] = srv.params
    for l_seq, l_eng in zip(logs["sequential"], logs[runtime]):
        assert (l_seq.selected == l_eng.selected).all()
        assert l_seq.energy_std == l_eng.energy_std
        assert l_seq.mean_bid == l_eng.mean_bid
        assert l_seq.server_reward == l_eng.server_reward
    assert _max_param_diff(params["sequential"], params[runtime]) < 1e-4


# ----------------------------------------------------------------------
# forced multi-device mesh (subprocess: XLA_FLAGS must precede jax init)
# ----------------------------------------------------------------------

_FORCED_MESH_SCRIPT = r"""
import jax, numpy as np, jax.numpy as jnp
assert jax.local_device_count() == 8, jax.local_device_count()
from repro.configs.base import FLConfig
from repro.core.adapters import cnn_adapter
from repro.core.server import FederatedServer
from repro.data.partition import partition_clients
from repro.data.synthetic import make_image_dataset

cfg = FLConfig(num_clients=10, num_clusters=3, select_ratio=0.4, rounds=2,
               local_epochs=2, sample_window=10, cluster_resamples=2,
               init_energy_mode="normal", scheme="random", seed=3)
train, test = make_image_dataset("mnist", n_train=700, n_test=120, seed=3)
adapter = cnn_adapter("mnist")
logs, params = {}, {}
for rt in ("vectorized", "sharded", "device"):
    clients = partition_clients(train.y, cfg, seed=3)
    srv = FederatedServer(cfg.replace(runtime=rt), adapter, train.x,
                          train.y, clients,
                          {"x": test.x[:64], "y": test.y[:64]})
    if rt in ("sharded", "device"):
        assert srv.runtime.engine.data_axis_size == 8, \
            srv.runtime.engine.data_axis_size
    if rt == "device":
        # every tier must split evenly across the 8-way data axis
        for c in srv.runtime.store.classes:
            assert all(t % 8 == 0 for t in c.tiers), c.tiers
    logs[rt] = srv.run()
    params[rt] = srv.params
for other in ("sharded", "device"):
    for l_v, l_s in zip(logs["vectorized"], logs[other]):
        assert (l_v.selected == l_s.selected).all()
        assert l_v.energy_std == l_s.energy_std
        assert l_v.mean_bid == l_s.mean_bid
    diff = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))),
        params["vectorized"], params[other])))
    assert diff < 1e-4, (other, diff)
print("FORCED_MESH_OK", diff)
"""


def test_sharded_runtime_on_forced_8_device_mesh():
    """Full-loop vectorized-vs-sharded equivalence on a real 8-way client
    split: identical selection logs, params within the reassociation
    tolerance.  Runs in a subprocess because the device-count flag only
    takes effect before first jax init (launch/mesh.py caveat)."""
    env = dict(os.environ)
    # drop any ambient device-count forcing, then append ours (XLA takes
    # the LAST occurrence, so a developer's exported =4 would win a
    # naive prepend)
    kept = [f for f in env.get("XLA_FLAGS", "").split()
            if not f.startswith("--xla_force_host_platform_device_count")]
    env["XLA_FLAGS"] = " ".join(
        kept + ["--xla_force_host_platform_device_count=8"])
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    r = subprocess.run([sys.executable, "-c", _FORCED_MESH_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "FORCED_MESH_OK" in r.stdout
