"""Per-architecture smoke tests (REDUCED configs, CPU): one forward + one
train step, asserting output shapes and no NaNs; decode-path consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models import model as MD
from repro.optim import apply_updates, sgd

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, B=2, S=32):
    b = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                     cfg.vocab_size),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.num_prefix_tokens:
        b["prefix_embeddings"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.num_prefix_tokens, cfg.d_model))
    if cfg.is_encdec:
        b["encoder_frames"] = jax.random.normal(
            jax.random.PRNGKey(4), (B, cfg.encoder_seq, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_no_nans(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    params = MD.init_params(cfg, KEY)
    B, S = 2, 32
    batch = _batch_for(cfg, B, S)
    logits = MD.logits_fn(cfg, params, batch["tokens"],
                          prefix_embeddings=batch.get("prefix_embeddings"),
                          encoder_frames=batch.get("encoder_frames"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_one_train_step(arch):
    cfg = get_smoke_config(arch)
    params = MD.init_params(cfg, KEY)
    batch = _batch_for(cfg)
    loss0, grads = jax.value_and_grad(
        lambda p: MD.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss0))
    for leaf in jax.tree.leaves(grads):
        assert not bool(jnp.isnan(leaf).any())
    init, upd = sgd(0.1)
    u, _ = upd(grads, init(params), params)
    params2 = apply_updates(params, u)
    loss1 = float(MD.loss_fn(cfg, params2, batch))
    assert np.isfinite(loss1)
    assert loss1 < float(loss0)      # one SGD step reduces the batch loss


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = MD.init_params(cfg, KEY)
    B, CL = 2, 16
    state = MD.init_decode_state(cfg, B, CL)
    if cfg.is_encdec:
        frames = jax.random.normal(
            jax.random.PRNGKey(4), (B, cfg.encoder_seq, cfg.d_model))
        state["cross"] = MD.build_cross_cache(
            cfg, params, MD.encode(cfg, params, frames))
    tok = jnp.zeros((B,), jnp.int32)
    for t in range(3):
        logits, state = MD.decode_step(cfg, params, state, tok,
                                       jnp.int32(t))
        tok = logits.argmax(-1).astype(jnp.int32)
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", [
    "starcoder2-3b", "qwen2-0.5b", "jamba-v0.1-52b", "xlstm-1.3b",
    "whisper-tiny"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode logits == full forward logits (non-MoE-routing
    archs; MoE tie-flips are tested separately)."""
    cfg = get_smoke_config(arch).replace(attn_impl="naive",
                                         moe_capacity_factor=8.0)
    params = MD.init_params(cfg, jax.random.PRNGKey(5))
    B, S = 2, 20
    toks = jax.random.randint(jax.random.PRNGKey(6), (B, S), 0,
                              cfg.vocab_size)
    kw = {}
    if cfg.is_encdec:
        kw["encoder_frames"] = jax.random.normal(
            jax.random.PRNGKey(7), (B, cfg.encoder_seq, cfg.d_model))
    full = MD.logits_fn(cfg, params, toks, **kw)
    state = MD.init_decode_state(cfg, B, S)
    if cfg.is_encdec:
        state["cross"] = MD.build_cross_cache(
            cfg, params, MD.encode(cfg, params, kw["encoder_frames"]))
    outs = []
    for t in range(S):
        lg, state = MD.decode_step(cfg, params, state, toks[:, t],
                                   jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(dec - full)) / jnp.max(jnp.abs(full)))
    assert rel < 2e-2, rel


@pytest.mark.parametrize("arch", ["qwen3-moe-235b-a22b",
                                  "granite-moe-3b-a800m"])
def test_decode_mostly_matches_forward_moe(arch):
    """MoE archs: decode vs forward agree except where the router sits on a
    top-k tie boundary (fp-order flips are inherent to discrete routing)."""
    cfg = get_smoke_config(arch).replace(attn_impl="naive",
                                         moe_capacity_factor=8.0)
    params = MD.init_params(cfg, jax.random.PRNGKey(5))
    B, S = 2, 20
    toks = jax.random.randint(jax.random.PRNGKey(6), (B, S), 0,
                              cfg.vocab_size)
    full = MD.logits_fn(cfg, params, toks)
    state = MD.init_decode_state(cfg, B, S)
    outs = []
    for t in range(S):
        lg, state = MD.decode_step(cfg, params, state, toks[:, t],
                                   jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    scale = float(jnp.max(jnp.abs(full)))
    per_pos = np.asarray(jnp.max(jnp.abs(dec - full), axis=(0, 2))) / scale
    assert (per_pos < 2e-2).mean() >= 0.7, per_pos


def test_sliding_window_limits_context():
    """starcoder2's sliding window: token far beyond the window cannot
    attend to the first tokens."""
    cfg = get_smoke_config("starcoder2-3b")
    assert cfg.sliding_window == 16
    params = MD.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(8), (1, 48), 0,
                              cfg.vocab_size)
    base = MD.logits_fn(cfg, params, toks)
    # perturb a token OUTSIDE the last token's window: no effect on last pos
    toks2 = toks.at[0, 5].set((toks[0, 5] + 1) % cfg.vocab_size)
    pert = MD.logits_fn(cfg, params, toks2)
    np.testing.assert_allclose(np.asarray(base[0, -1]),
                               np.asarray(pert[0, -1]), atol=1e-5)
    # perturb INSIDE the window: must change the last position
    toks3 = toks.at[0, 40].set((toks[0, 40] + 1) % cfg.vocab_size)
    pert3 = MD.logits_fn(cfg, params, toks3)
    assert float(jnp.max(jnp.abs(base[0, -1] - pert3[0, -1]))) > 1e-6


def test_full_configs_match_assignment_table():
    """The full (non-smoke) configs carry the exact assigned dimensions."""
    expect = {
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }
    for arch, (L, D, H, KV, FF, V) in expect.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads,
                cfg.num_kv_heads, cfg.d_ff, cfg.vocab_size) == \
            (L, D, H, KV, FF, V), arch
    moe = get_config("qwen3-moe-235b-a22b")
    assert (moe.num_experts, moe.experts_per_token) == (128, 8)
    gran = get_config("granite-moe-3b-a800m")
    assert (gran.num_experts, gran.experts_per_token) == (40, 8)
    jam = get_config("jamba-v0.1-52b")
    assert (jam.num_experts, jam.experts_per_token) == (16, 2)
    assert sum(b.mixer == "attn" for b in jam.cycle) == 1   # 1:7 interleave
    assert sum(b.mixer == "mamba" for b in jam.cycle) == 7
