"""Self-healing server (ISSUE 10): adaptive adversaries
(sub_clip/alie/on_off), the auto-tuned MAD-band screen, reputation-priced
bidding, the divergence watchdog's checkpoint-ring rollback, and the
buffered-aggregation x quarantine mass fix — plus the neutrality
boundaries each of them must respect."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs.base import FLConfig
from repro.core import aggregation as AGG
from repro.core import auction as A
from repro.core import rounds as RND
from repro.core.adapters import cnn_adapter
from repro.core.server import FederatedServer, _BufferedUpdate
from repro.data.partition import partition_clients
from repro.data.synthetic import make_image_dataset
from repro.obs.schema import load_jsonl, validate_events
from repro.sim import dynamics as DYN

RUNTIMES = ("sequential", "vectorized", "sharded", "device")
N_CLIENTS = 10
POOL = 700


@pytest.fixture(autouse=True)
def _obs_reset():
    obs.OBS.reset()
    yield
    obs.OBS.reset()


def _cfg(**kw):
    base = dict(num_clients=N_CLIENTS, num_clusters=3, select_ratio=0.4,
                rounds=3, local_epochs=1, sample_window=10,
                cluster_resamples=2, init_energy_mode="normal", seed=3)
    base.update(kw)
    return FLConfig(**base)


@pytest.fixture(scope="module")
def data():
    train, test = make_image_dataset("mnist", n_train=POOL, n_test=120,
                                     seed=3)
    return train, test


def _server(cfg, data):
    train, test = data
    clients = partition_clients(train.y, cfg, seed=3)
    return FederatedServer(cfg, cnn_adapter("mnist"), train.x, train.y,
                           clients, {"x": test.x[:64], "y": test.y[:64]})


def _leaves(params):
    return [np.asarray(x) for x in jax.tree.leaves(params)]


def _assert_trees_equal(a, b):
    for x, y in zip(_leaves(a), _leaves(b)):
        np.testing.assert_array_equal(x, y)


# ----------------------------------------------------------------------
# adaptive attack semantics (sim.dynamics)
# ----------------------------------------------------------------------

def _rows():
    rng = np.random.default_rng(0)
    deltas = jnp.asarray(rng.normal(size=(6, 8)).astype(np.float32))
    adv = jnp.array([True, False, True, False, False, False])
    valid = jnp.array([True, True, False, True, True, True])
    return deltas, adv, valid   # only row 0 is adv AND valid


def test_sub_clip_sits_under_the_clip_threshold():
    cfg = _cfg(adversary_frac=0.3, attack="sub_clip", clip_mult=2.0,
               sub_clip_margin=0.9)
    deltas, adv, valid = _rows()
    clip_ema = jnp.float32(1.7)
    out = np.asarray(DYN.corrupt_updates(cfg, jax.random.PRNGKey(1),
                                         deltas, adv, valid,
                                         clip_ema=clip_ema,
                                         round_idx=jnp.int32(0)))
    ref = np.asarray(deltas)
    np.testing.assert_array_equal(out[1:], ref[1:])   # honest untouched
    norm = float(np.linalg.norm(out[0]))
    # the malicious row's norm lands exactly at margin * clip threshold
    assert norm == pytest.approx(0.9 * 2.0 * 1.7, rel=1e-5)
    # and it pushes AGAINST the honest mean direction
    honest = ref[[1, 3, 4, 5]].mean(axis=0)
    assert float(out[0] @ honest) < 0


def test_sub_clip_falls_back_to_median_norm_when_unseeded():
    cfg = _cfg(adversary_frac=0.3, attack="sub_clip", clip_mult=2.0,
               sub_clip_margin=0.9)
    deltas, adv, valid = _rows()
    # clip EMA 0 (round 0, unseeded): target scales off the honest
    # median norm instead of a zero threshold
    out = np.asarray(DYN.corrupt_updates(cfg, jax.random.PRNGKey(1),
                                         deltas, adv, valid,
                                         clip_ema=jnp.float32(0.0),
                                         round_idx=jnp.int32(0)))
    honest_norms = np.linalg.norm(np.asarray(deltas)[[1, 3, 4, 5]], axis=1)
    # the on-device median is the lower-middle order statistic
    # (index floor((v-1)/2)), not numpy's interpolated midpoint
    med = float(np.sort(honest_norms)[1])
    assert float(np.linalg.norm(out[0])) == pytest.approx(0.9 * 2.0 * med,
                                                          rel=1e-4)


def test_alie_row_is_mean_minus_z_std():
    cfg = _cfg(adversary_frac=0.3, attack="alie", alie_z=1.5)
    deltas, adv, valid = _rows()
    out = np.asarray(DYN.corrupt_updates(cfg, jax.random.PRNGKey(1),
                                         deltas, adv, valid))
    ref = np.asarray(deltas)
    np.testing.assert_array_equal(out[1:], ref[1:])
    honest = ref[[1, 3, 4, 5]]
    expect = honest.mean(axis=0) - 1.5 * honest.std(axis=0)
    np.testing.assert_allclose(out[0], expect, rtol=1e-4, atol=1e-5)


def test_on_off_alternates_phases():
    cfg = _cfg(adversary_frac=0.3, attack="on_off", onoff_period=2,
               attack_scale=5.0)
    deltas, adv, valid = _rows()
    key = jax.random.PRNGKey(1)
    ref = np.asarray(deltas)
    for r, active in ((0, True), (1, True), (2, False), (3, False),
                      (4, True)):
        out = np.asarray(DYN.corrupt_updates(cfg, key, deltas, adv, valid,
                                             round_idx=jnp.int32(r)))
        if active:
            np.testing.assert_array_equal(out[0], 5.0 * ref[0])
        else:
            np.testing.assert_array_equal(out[0], ref[0])
        np.testing.assert_array_equal(out[1:], ref[1:])


# ----------------------------------------------------------------------
# auto-tuned screening (core.aggregation)
# ----------------------------------------------------------------------

def _screen_inputs(cfg, deltas, weights, valid, adv=None, dstate=None,
                   round_idx=0):
    cap = deltas.shape[0]
    adv = np.zeros(cap, bool) if adv is None else np.asarray(adv)
    ids = np.where(np.asarray(valid), np.arange(cap), -1).astype(np.int32)
    strikes = jnp.zeros((cfg.num_clients,), jnp.float32)
    if dstate is None:
        dstate = AGG.init_defense_state(cfg)
    return (jnp.asarray(deltas, jnp.float32),
            jnp.asarray(weights, jnp.float32), jnp.asarray(valid),
            jnp.asarray(adv), jnp.asarray(ids), strikes, dstate,
            jnp.int32(round_idx), jax.random.PRNGKey(0))


def _tight_cohort(attacker_norm=5.0):
    """8 honest rows with tightly-spread norms ~1 plus one attacker row
    at ``attacker_norm`` — inside a loose static clip threshold, far
    outside the honest MAD band."""
    rng = np.random.default_rng(5)
    deltas = rng.normal(size=(9, 16)).astype(np.float32)
    deltas /= np.linalg.norm(deltas, axis=1, keepdims=True)
    deltas[1:] *= rng.uniform(0.95, 1.05, size=(8, 1)).astype(np.float32)
    deltas[0] *= attacker_norm
    w = np.full(9, 1 / 9, np.float32)
    return deltas, w, np.ones(9, bool)


def test_adaptive_band_catches_sub_threshold_outlier():
    # static clip with a loose multiplier lets a 5x-median row through
    # with only norm-clipping... at clip_mult=8 it is not even clipped
    deltas, w, valid = _tight_cohort(attacker_norm=5.0)
    cfg_s = _cfg(defense="clip", clip_mult=8.0, defense_mode="static")
    _, strikes_s, _, rep_s = AGG.make_screened_step(cfg_s)(
        *_screen_inputs(cfg_s, deltas, w, valid))
    assert int(rep_s["num_screened"]) == 0
    assert float(rep_s["clipped_frac"]) == 0.0
    assert not np.asarray(strikes_s).any()

    # ...the adaptive band excludes it outright and strikes the sender
    cfg_a = _cfg(defense="clip", clip_mult=8.0, defense_mode="adaptive",
                 outlier_strike=0.5)
    # seed the running stats so round-0 has a band to screen against
    ds = AGG.DefenseState(clip_ema=jnp.float32(1.0),
                          mad_ema=jnp.float32(0.02),
                          pressure=jnp.float32(0.0), tighten=None)
    agg, strikes_a, ds2, rep_a = AGG.make_screened_step(cfg_a)(
        *_screen_inputs(cfg_a, deltas, w, valid, dstate=ds))
    assert int(rep_a["num_screened"]) == 1
    assert int(rep_a["num_survivors"]) == 8
    s = np.asarray(strikes_a)
    assert s[0] == pytest.approx(0.5) and s.sum() == pytest.approx(0.5)
    # the excluded row carries no weight in the aggregate
    assert float(np.linalg.norm(np.asarray(agg))) < 2.0
    # rejection raised the pressure EMA, which tightens the next band
    assert float(ds2.pressure) > 0.0
    assert float(rep_a["defense_pressure"]) == pytest.approx(
        float(ds2.pressure))


def test_adaptive_band_admits_clean_cohort():
    deltas, w, valid = _tight_cohort(attacker_norm=1.0)   # no outlier
    cfg = _cfg(defense="clip", defense_mode="adaptive")
    ds = AGG.DefenseState(clip_ema=jnp.float32(1.0),
                          mad_ema=jnp.float32(0.02),
                          pressure=jnp.float32(0.0), tighten=None)
    _, strikes, ds2, rep = AGG.make_screened_step(cfg)(
        *_screen_inputs(cfg, deltas, w, valid, dstate=ds))
    assert int(rep["num_screened"]) == 0
    assert int(rep["num_survivors"]) == 9
    assert not np.asarray(strikes).any()
    assert float(rep["survivor_frac"]) == 1.0
    # pressure decays toward zero on clean rounds
    assert float(ds2.pressure) <= float(ds.pressure)


def test_pressure_tightens_k_eff():
    # same cohort, same stats: a borderline outlier survives at zero
    # pressure and is screened once the pressure EMA is high
    deltas, w, valid = _tight_cohort(attacker_norm=1.3)
    cfg = _cfg(defense="clip", clip_mult=8.0, defense_mode="adaptive",
               adapt_k=3.0, adapt_gain=4.0)
    screen = AGG.make_screened_step(cfg)
    ds_lo = AGG.DefenseState(clip_ema=jnp.float32(1.0),
                             mad_ema=jnp.float32(0.2),
                             pressure=jnp.float32(0.0), tighten=None)
    ds_hi = AGG.DefenseState(clip_ema=jnp.float32(1.0),
                             mad_ema=jnp.float32(0.2),
                             pressure=jnp.float32(1.0), tighten=None)
    _, _, _, rep_lo = screen(*_screen_inputs(cfg, deltas, w, valid,
                                             dstate=ds_lo))
    _, _, _, rep_hi = screen(*_screen_inputs(cfg, deltas, w, valid,
                                             dstate=ds_hi))
    assert int(rep_lo["num_screened"]) == 0
    assert int(rep_hi["num_screened"]) == 1


def test_static_mode_trace_unchanged_by_adaptive_knobs():
    # defense_mode='static' must ignore every adaptive knob: identical
    # aggregates, strikes and clip EMA vs a default-knob config
    deltas, w, valid = _tight_cohort(attacker_norm=5.0)
    cfg1 = _cfg(defense="clip")
    cfg2 = _cfg(defense="clip", adapt_k=0.1, adapt_gain=99.0,
                outlier_strike=7.0)
    o1 = AGG.make_screened_step(cfg1)(*_screen_inputs(cfg1, deltas, w,
                                                      valid))
    o2 = AGG.make_screened_step(cfg2)(*_screen_inputs(cfg2, deltas, w,
                                                      valid))
    np.testing.assert_array_equal(np.asarray(o1[0]), np.asarray(o2[0]))
    np.testing.assert_array_equal(np.asarray(o1[1]), np.asarray(o2[1]))
    assert float(o1[2].clip_ema) == float(o2[2].clip_ema)
    assert o1[2].mad_ema is None and o2[2].mad_ema is None


# ----------------------------------------------------------------------
# reputation-priced bidding (core.auction / schemes)
# ----------------------------------------------------------------------

def test_effective_bids_identity_in_ban_mode():
    cfg = _cfg(adversary_frac=0.3, attack="nan", defense="median")
    bids = jnp.array([0.1, 0.2, 0.3])
    strikes = jnp.array([5.0, 0.0, 0.0])
    assert A.effective_bids(bids, strikes, cfg) is bids     # same object
    assert A.effective_bids(bids, None, cfg) is bids


def test_effective_bids_price_inflation_preserves_inf():
    cfg = _cfg(adversary_frac=0.3, attack="nan", defense="median",
               reputation_mode="price", rep_price_gain=2.0)
    bids = jnp.array([0.1, 0.2, float(A.INF)])
    strikes = jnp.array([3.0, 0.0, 0.0])
    eff = np.asarray(A.effective_bids(bids, strikes, cfg))
    assert eff[0] == pytest.approx(0.1 * 7.0)   # 1 + 2*3
    assert eff[1] == pytest.approx(0.2)         # clean: true bid
    assert eff[2] == float(A.INF)               # ineligible stays INF


def test_price_mode_flips_auction_winner():
    cfg = _cfg(reputation_mode="price", rep_price_gain=1.0)
    clusters = jnp.zeros(4, jnp.int32)
    eligible = jnp.ones(4, bool)
    bids = jnp.array([0.10, 0.15, 0.30, 0.40])
    tie = jnp.zeros(4)
    strikes = jnp.array([2.0, 0.0, 0.0, 0.0])   # cheapest client tainted
    win_true = np.asarray(A.cluster_winners(bids, clusters, eligible, 1,
                                            cfg.num_clusters,
                                            tie_break=tie))
    win_eff = np.asarray(A.cluster_winners(
        A.effective_bids(bids, strikes, cfg), clusters, eligible, 1,
        cfg.num_clusters, tie_break=tie))
    assert win_true[0] and not win_true[1]
    # 0.10 * (1 + 2) = 0.30 ties client 2's true bid; client 1 at 0.15
    # is now the cheapest effective bid
    assert win_eff[1] and not win_eff[0]


def test_ban_mode_run_bit_identical_to_pre_pricing(data):
    # reputation_mode='ban' (default) must reproduce the PR 8 strike/ban
    # behavior bit-exactly even though the pricing hook is in the trace
    cfg = _cfg(rounds=6, adversary_frac=0.3, attack="nan",
               defense="median", strike_threshold=1.0, strike_decay=1.0)
    srv = _server(cfg, data)
    adv = np.asarray(obs.device_get(DYN.adversary_mask(cfg)), bool)
    logs = srv.run(rounds=6)
    strikes = np.asarray(obs.device_get(srv.state.strikes))
    assert (strikes[~adv] == 0).all()
    banned_at = {}
    for log in logs:
        for c in log.selected:
            assert int(c) not in banned_at
        for c in log.selected:
            if adv[int(c)]:
                banned_at.setdefault(int(c), log.round + 1)
    assert banned_at    # the ban machinery actually engaged


def test_price_mode_keeps_struck_clients_biddable(data):
    # same attack, price mode: no hard ban — a struck adversary can
    # still appear in later selections (priced, not excluded)
    cfg = _cfg(rounds=6, adversary_frac=0.3, attack="nan",
               defense="median", strike_threshold=1.0, strike_decay=1.0,
               reputation_mode="price", rep_price_gain=0.1)
    srv = _server(cfg, data)
    adv = np.asarray(obs.device_get(DYN.adversary_mask(cfg)), bool)
    logs = srv.run(rounds=6)
    strikes = np.asarray(obs.device_get(srv.state.strikes))
    assert (strikes[~adv] == 0).all()          # honest never struck
    struck_then_selected = False
    seen_struck = set()
    for log in logs:
        for c in log.selected:
            if int(c) in seen_struck:
                struck_then_selected = True
        for c in log.selected:
            if adv[int(c)]:
                seen_struck.add(int(c))
    assert struck_then_selected    # ban mode would have excluded them


# ----------------------------------------------------------------------
# neutrality property: adversary_frac 0 => trust constant, selection
# bit-identical to defense-off — all four runtimes + the scan fast path
# ----------------------------------------------------------------------

@pytest.mark.parametrize("runtime", RUNTIMES)
@pytest.mark.parametrize("rep_mode", ("ban", "price"))
def test_frac0_trust_constant_and_selection_identical(runtime, rep_mode,
                                                      data):
    plain = _server(_cfg(runtime=runtime, rounds=3), data)
    logs_p = plain.run(rounds=3)
    srv = _server(_cfg(runtime=runtime, rounds=3, adversary_frac=0.0,
                       attack="none", defense="median",
                       reputation_mode=rep_mode), data)
    assert srv.defended                        # strikes ledger active
    logs_d = srv.run(rounds=3)
    # no clean client's trust ever decreases: zero strikes throughout
    strikes = np.asarray(obs.device_get(srv.state.strikes))
    assert (strikes == 0).all()
    # selection stays bit-identical to the defense-off run (the trust
    # gate / bid pricing are exact no-ops at zero strikes)
    for lp, ld in zip(logs_p, logs_d):
        np.testing.assert_array_equal(lp.selected, ld.selected)
        assert lp.mean_bid == ld.mean_bid


@pytest.mark.parametrize("rep_mode", ("ban", "price"))
def test_frac0_scan_fast_path_identical(rep_mode):
    import dataclasses
    cfg = _cfg(num_clients=64, num_clusters=4, reputation_mode=rep_mode)
    key = jax.random.PRNGKey(11)
    state0 = RND.synthetic_fleet(cfg, key)
    kr = jax.random.fold_in(key, 1)
    _, m_plain, w_plain = RND.simulate_rounds(state0, cfg, kr, 5,
                                              record_wins=True)
    state_s = dataclasses.replace(
        state0, strikes=jnp.zeros((cfg.num_clients,), jnp.float32))
    final, m_def, w_def = RND.simulate_rounds(state_s, cfg, kr, 5,
                                              record_wins=True)
    np.testing.assert_array_equal(np.asarray(w_plain), np.asarray(w_def))
    np.testing.assert_array_equal(np.asarray(m_plain["mean_bid"]),
                                  np.asarray(m_def["mean_bid"]))
    # trust stayed 1.0 every round (strikes never grow without a screen)
    assert np.asarray(m_def["trust_min"]).min() == 1.0
    assert (np.asarray(obs.device_get(final.strikes)) == 0).all()


# optional hypothesis sweep over seeds (repo convention: skip without
# the extra — tests/test_clustering.py does the same)
try:
    import hypothesis  # noqa: F401
    import hypothesis.strategies as st
    from hypothesis import given, settings

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2 ** 16))
    def test_property_frac0_scan_trust_never_decreases(seed):
        import dataclasses
        cfg = _cfg(num_clients=32, num_clusters=4, seed=int(seed) % 97,
                   reputation_mode="price")
        key = jax.random.PRNGKey(int(seed))
        state0 = RND.synthetic_fleet(cfg, key)
        kr = jax.random.fold_in(key, 1)
        state_s = dataclasses.replace(
            state0, strikes=jnp.zeros((cfg.num_clients,), jnp.float32))
        _, m_plain, w_plain = RND.simulate_rounds(state0, cfg, kr, 4,
                                                  record_wins=True)
        final, m_def, w_def = RND.simulate_rounds(state_s, cfg, kr, 4,
                                                  record_wins=True)
        np.testing.assert_array_equal(np.asarray(w_plain),
                                      np.asarray(w_def))
        assert np.asarray(m_def["trust_min"]).min() == 1.0
except ImportError:
    pass


# ----------------------------------------------------------------------
# divergence watchdog (core.server)
# ----------------------------------------------------------------------

def test_watchdog_rolls_back_nan_storm_and_run_completes(data, tmp_path):
    path = str(tmp_path / "wd.jsonl")
    obs.OBS.configure(jsonl=path, memory=True)
    cfg = _cfg(rounds=4, eval_every=1, adversary_frac=0.3, attack="nan",
               defense="none", watchdog="on", watchdog_ring=3)
    srv = _server(cfg, data)
    logs = srv.run(rounds=4)
    obs.OBS.flush()
    # the run finished every round despite params going non-finite...
    assert [l.round for l in logs] == [0, 1, 2, 3]
    assert srv.watchdog_totals["rollbacks"] >= 1
    # ...and the final params are the restored healthy snapshot
    for lf in _leaves(srv.params):
        assert np.isfinite(lf).all()
    events = load_jsonl(path)
    rb = [e for e in events if e.get("kind") == "watchdog"
          and e.get("name") == "rollback"]
    assert rb and all(isinstance(e.get("reason"), str) for e in rb)
    assert rb[0]["reason"] == "non_finite_eval"
    assert validate_events(events, rounds=4, eval_every=1,
                           min_rollbacks=1) == []


def test_watchdog_rollback_decays_lr_and_tightens(data):
    cfg = _cfg(rounds=3, eval_every=1, adversary_frac=0.3, attack="nan",
               defense="clip", clip_mult=1e9, watchdog="on",
               watchdog_lr_decay=0.5, watchdog_tighten=2.0)
    srv = _server(cfg, data)
    assert float(srv._srv_lr) == 1.0
    ds0 = srv._defense_state
    assert float(ds0.tighten) == 1.0           # watchdog threads tighten
    srv._wd_snapshot(-1)
    srv._wd_rollback("loss_spike", 0)
    assert float(srv._srv_lr) == 0.5
    assert float(srv._defense_state.tighten) == 2.0
    srv._wd_rollback("loss_spike", 1)
    assert float(srv._srv_lr) == 0.25
    assert float(srv._defense_state.tighten) == 4.0


def test_watchdog_on_clean_run_bit_identical_to_off(data):
    # no rollback ever fires on a clean run, and the server-LR hooks are
    # exact no-ops at lr=1.0 — params and selections match bit-for-bit
    off = _server(_cfg(rounds=3), data)
    logs_off = off.run(rounds=3)
    on = _server(_cfg(rounds=3, watchdog="on"), data)
    logs_on = on.run(rounds=3)
    assert on.watchdog_totals["rollbacks"] == 0
    assert on.watchdog_totals["snapshots"] >= 1
    _assert_trees_equal(off.params, on.params)
    for lo, ln in zip(logs_off, logs_on):
        np.testing.assert_array_equal(lo.selected, ln.selected)
        assert lo.mean_bid == ln.mean_bid
        assert lo.test_acc == pytest.approx(ln.test_acc, nan_ok=True)


def test_watchdog_defended_clean_run_bit_identical_to_off(data):
    # same boundary through the DEFENDED path: the screen carries a
    # tighten factor (1.0) and the delta scales by srv_lr (1.0) — both
    # exact identities until a rollback actually fires
    cfg_off = _cfg(rounds=3, adversary_frac=0.3, attack="scale",
                   defense="trimmed")
    off = _server(cfg_off, data)
    off.run(rounds=3)
    on = _server(_cfg(rounds=3, adversary_frac=0.3, attack="scale",
                      defense="trimmed", watchdog="on"), data)
    on.run(rounds=3)
    assert on.watchdog_totals["rollbacks"] == 0
    _assert_trees_equal(off.params, on.params)
    np.testing.assert_array_equal(
        np.asarray(obs.device_get(off.state.strikes)),
        np.asarray(obs.device_get(on.state.strikes)))


def test_watchdog_checkpoint_roundtrip(data, tmp_path):
    # defense_state + server_lr ride the checkpoint tree; a resumed
    # watchdog run continues from the restored values
    cfg = _cfg(rounds=4, adversary_frac=0.3, attack="scale",
               defense="clip", defense_mode="adaptive", watchdog="on")
    path = str(tmp_path / "wd_ck")
    ref = _server(cfg, data)
    ref.run(rounds=4)
    crashed = _server(cfg, data)
    crashed.run(rounds=3, checkpoint_every=2, checkpoint_path=path)
    resumed = _server(cfg, data)
    resumed.run(rounds=4, checkpoint_path=path, resume=True)
    _assert_trees_equal(ref.params, resumed.params)
    assert float(ref._defense_state.clip_ema) == float(
        resumed._defense_state.clip_ema)
    assert float(ref._defense_state.pressure) == float(
        resumed._defense_state.pressure)
    assert float(ref._srv_lr) == float(resumed._srv_lr)


# ----------------------------------------------------------------------
# buffered aggregation x quarantine (satellite fix)
# ----------------------------------------------------------------------

def _dyn_buffered_cfg(**kw):
    base = dict(rounds=4, churn=0.0, deadline=1.1, aggregation="buffered",
                buffer_goal=1, buffer_timeout=1, adversary_frac=0.3,
                attack="nan", defense="median")
    base.update(kw)
    return _cfg(**base)


def test_fully_quarantined_late_cohort_folds_zero_mass(data):
    mem = obs.OBS.configure(memory=True)
    srv = _server(_dyn_buffered_cfg(), data)
    params0 = srv.params
    # a parked late update whose every row was quarantined: survivor
    # fraction 0 -> the fold must drop it, not divide 0/0 or pull the
    # params toward the (zeroed) delta
    poisoned = jax.tree.map(jnp.ones_like, srv.params)
    srv._late_buffer.append(_BufferedUpdate(
        delta=poisoned, mass=500.0, round=0, arrival=1,
        mass_scale=jnp.float32(0.0)))
    folded = srv._maybe_fold_buffer(2, force=True)
    assert folded == 0
    assert srv._late_buffer == []              # dropped, not retried
    _assert_trees_equal(params0, srv.params)   # params untouched
    # the drop is loud: counter + dynamics event mark it for the schema
    assert obs.OBS.counters.get("dyn/buffer_all_quarantined", 0) == 1
    obs.OBS.flush()
    names = [e.get("name") for e in mem.events
             if e.get("kind") == "dynamics"]
    assert "buffer/all_quarantined" in names


def test_partially_quarantined_late_cohort_scales_mass(data):
    srv = _server(_dyn_buffered_cfg(), data)
    params0 = srv.params
    ones = jax.tree.map(jnp.ones_like, srv.params)
    # two entries, equal raw mass: one fully screened out, one intact —
    # the fold weight must come ONLY from the intact entry
    srv._late_buffer.append(_BufferedUpdate(
        delta=ones, mass=100.0, round=1, arrival=2,
        mass_scale=jnp.float32(0.0)))
    srv._late_buffer.append(_BufferedUpdate(
        delta=ones, mass=100.0, round=1, arrival=2,
        mass_scale=jnp.float32(1.0)))
    folded = srv._maybe_fold_buffer(2, force=True)
    assert folded == 2
    w = DYN.staleness_weight(srv.cfg, 1)
    for a, b in zip(_leaves(params0), _leaves(srv.params)):
        np.testing.assert_allclose(b, a + float(w), rtol=1e-6)


def test_buffered_defended_run_stays_finite(data):
    # end-to-end: NaN adversaries + deadline misses + buffered folds —
    # the defended fold path must never push non-finite params
    srv = _server(_dyn_buffered_cfg(rounds=4), data)
    logs = srv.run(rounds=4)
    assert len(logs) == 4
    for lf in _leaves(srv.params):
        assert np.isfinite(lf).all()


# ----------------------------------------------------------------------
# compile-once: adaptive screen + watchdog keep the warm loop trace-free
# ----------------------------------------------------------------------

def test_device_selfheal_warm_loop_zero_retrace(data):
    cfg = _cfg(runtime="device", rounds=8, adversary_frac=0.3,
               attack="sub_clip", defense="clip",
               defense_mode="adaptive", reputation_mode="price",
               watchdog="on")
    srv = _server(cfg, data)
    base = obs.jax_stats.snapshot()
    srv.run(rounds=3)
    snap = obs.jax_stats.snapshot()
    assert obs.jax_stats.delta(base).get("traces/screened_agg") == 1
    with obs.sync_audit():                 # no implicit host transfers
        for t in range(3, 8):              # shifting cohorts, warm
            srv._dispatch_round(t, eval_now=False)
    srv._flush_pending()
    d = obs.jax_stats.delta(snap)
    retraces = {k: v for k, v in d.items() if k.startswith("traces")}
    assert not retraces, retraces
