"""Device-resident fleet pipeline (repro.sim.fleet + the ``device``
runtime): plan-cache/oracle bit-equality, capacity-class invariants, the
compile-once guarantee (zero retraces across shifting cohorts), and the
server's async round loop (fused eval, eval cadence, deferred metric
fetches)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core.adapters import cnn_adapter
from repro.core.server import FederatedServer
from repro.data.partition import partition_clients
from repro.data.synthetic import make_image_dataset
from repro.sim.cohort import HostPlanCache, oracle_batch_plan
from repro.sim.fleet import FleetStore
from repro.sim.runtime import make_runtime

N_CLIENTS = 10
POOL = 700


def _cfg(**kw):
    base = dict(num_clients=N_CLIENTS, num_clusters=3, select_ratio=0.4,
                rounds=2, local_epochs=2, sample_window=10,
                cluster_resamples=2, init_energy_mode="normal", seed=3)
    base.update(kw)
    return FLConfig(**base)


@pytest.fixture(scope="module")
def data():
    train, test = make_image_dataset("mnist", n_train=POOL, n_test=120,
                                     seed=3)
    return train, test


@pytest.fixture(scope="module")
def clients(data):
    train, _ = data
    return partition_clients(train.y, _cfg(), seed=3)


# ----------------------------------------------------------------------
# host plan cache: permutation-only rebuild == the oracle's full plan
# ----------------------------------------------------------------------

def test_plan_cache_matches_oracle(data, clients):
    train, _ = data
    cfg = _cfg()
    cache = HostPlanCache(train.x, train.y, clients, cfg.local_epochs)
    for i in range(N_CLIENTS):
        for hist in (0, 1, 5):
            n = clients[i].size
            bs = min(32, n)
            rng = np.random.default_rng(hist * 977 + i)
            ref = oracle_batch_plan(n, bs, cfg.local_epochs, rng)
            got = cache.plan(i, hist)
            assert (got == ref).all()
            # local gather == global gather through the shard
            xl, yl = cache.local_data(i)
            shard = np.asarray(clients[i].train_idx)
            assert (xl[got] == train.x[shard[ref]]).all()
            assert (yl[got] == train.y[shard[ref]]).all()


# ----------------------------------------------------------------------
# capacity classes: static cover of the fleet
# ----------------------------------------------------------------------

def test_capacity_classes_cover_fleet(data, clients):
    train, _ = data
    cfg = _cfg()
    store = FleetStore(train.x, train.y, clients, cfg)
    seen = set()
    for cls_id, c in enumerate(store.classes):
        for r, gid in enumerate(c.members):
            assert store.class_of[gid] == cls_id
            assert store.row_of[gid] == r
            assert gid not in seen
            seen.add(int(gid))
            # the client's whole plan fits the class capacities
            n = clients[gid].size
            assert min(32, n) == c.bs
            assert n <= c.n_cap
            total = (n // c.bs) * cfg.local_epochs
            assert total <= c.step_cap
            # the resident row is exactly the client's local shard
            xl, yl = store.cache.local_data(int(gid))
            assert (np.asarray(c.x[r, :n]) == xl).all()
            assert (np.asarray(c.y[r, :n]) == yl).all()
        assert c.tiers == sorted(set(c.tiers))
        assert c.step_cap % 4 == 0
    assert seen == {i for i in range(N_CLIENTS) if clients[i].size > 0}


def test_assemble_weights_and_masks(data, clients):
    train, _ = data
    cfg = _cfg()
    store = FleetStore(train.x, train.y, clients, cfg)
    sel = np.arange(N_CLIENTS)
    hist = np.arange(N_CLIENTS) % 3
    batches = store.assemble(sel, hist)
    sizes = np.array([c.size for c in clients], np.float64)
    pk = sizes / sizes.sum()
    seen = {}
    total_w = 0.0
    for b in batches:
        c = store.classes[b.cls_id]
        assert len(b.rows) in c.tiers
        for r, gid in enumerate(b.client_idx):
            if gid < 0:                       # padding row: fully masked
                assert b.step_mask[r].sum() == 0
                assert b.weights[r] == 0
                continue
            n = clients[gid].size
            steps = (n // min(32, n)) * cfg.local_epochs
            assert b.rows[r] == store.row_of[gid]
            assert b.step_mask[r].sum() == steps
            assert b.weights[r] == pytest.approx(pk[gid])
            seen[int(gid)] = seen.get(int(gid), 0) + 1
        total_w += float(b.weights.sum())
    assert sorted(seen) == list(range(N_CLIENTS))   # each winner once
    assert total_w == pytest.approx(1.0)
    assert store.assemble(np.array([], np.int64), hist) == []


# ----------------------------------------------------------------------
# compile-once policy: zero retraces across shifting cohorts
# ----------------------------------------------------------------------

def test_device_runtime_zero_retrace_across_shifting_cohorts(data,
                                                             clients):
    train, _ = data
    cfg = _cfg(runtime="device")
    adapter = cnn_adapter("mnist")
    params = adapter.init(jax.random.PRNGKey(0))
    rt = make_runtime(cfg, adapter, train.x, train.y, clients)
    rt.warmup(params)
    warm = dict(rt.engine.stats)
    assert warm["traces"] == sum(len(c.tiers) for c in rt.store.classes)
    hist = np.zeros(N_CLIENTS, np.int64)
    # 3+ rounds with shifting cohort sizes AND compositions, including
    # one bigger than any tier (chunked invocations reuse the shapes)
    for sel in (np.arange(N_CLIENTS), np.array([0, 3]),
                np.array([1, 4, 6, 7, 9]), np.array([2])):
        p = rt.train_cohort(params, sel, hist)
        assert p is not None
        hist[sel] += 1
    after = rt.engine.stats
    assert after["traces"] == warm["traces"], (warm, after)
    assert after["shape_misses"] == warm["shape_misses"], (warm, after)
    assert after["shape_hits"] > warm["shape_hits"]


# ----------------------------------------------------------------------
# async server loop: fused eval, cadence, deferred fetches
# ----------------------------------------------------------------------

def _server(cfg, data):
    train, test = data
    clients = partition_clients(train.y, cfg, seed=3)
    return FederatedServer(cfg, cnn_adapter("mnist"), train.x, train.y,
                           clients, {"x": test.x[:64], "y": test.y[:64]})


def test_fused_eval_matches_separate_calls(data):
    srv = _server(_cfg(), data)
    acc, loss = jax.device_get(srv._eval_step(srv.params, srv._test_dev))
    assert float(acc) == float(srv.adapter.accuracy(srv.params,
                                                    srv.test_batch))
    assert float(loss) == float(srv.adapter.loss(srv.params,
                                                 srv.test_batch))


@pytest.mark.parametrize("runtime", ("sequential", "device"))
def test_eval_every_cadence_and_equivalence(data, runtime):
    """eval_every>1 must change ONLY which rounds carry eval scalars:
    selection/energy logs and final params stay identical, skipped
    rounds log NaN, the final round always evaluates."""
    rounds = 5
    every = _server(_cfg(runtime=runtime, rounds=rounds), data)
    sparse = _server(_cfg(runtime=runtime, rounds=rounds, eval_every=3),
                     data)
    logs_e = every.run()
    logs_s = sparse.run()
    assert [not math.isnan(l.test_acc) for l in logs_s] == \
        [True, False, False, True, True]
    for le, ls in zip(logs_e, logs_s):
        assert (le.selected == ls.selected).all()
        assert le.energy_std == ls.energy_std
        assert le.mean_bid == ls.mean_bid
        assert le.client_reward_sum == ls.client_reward_sum
        if not math.isnan(ls.test_acc):
            assert le.test_acc == ls.test_acc
            assert le.test_loss == ls.test_loss
    diff = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))),
        every.params, sparse.params)))
    assert diff == 0.0
    assert every.total_client_reward == pytest.approx(
        sparse.total_client_reward)


def test_run_round_flushes_immediately(data):
    srv = _server(_cfg(runtime="device"), data)
    log = srv.run_round(0)
    assert srv._pending == []
    assert log.round == 0 and np.isfinite(log.test_acc)
    assert len(srv.logs) == 1
