"""Fused round control plane tests (repro.core.rounds + auction winner
selection): segmented cluster_winners vs the per-cluster loop oracle,
lexicographic tie-breaking, zero-winner reward guards, and scan-path vs
seed per-round-path equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core import auction as A
from repro.core import rounds as R
from repro.core import selection as SEL


# ----------------------------------------------------------------------
# segmented cluster_winners vs the loop oracle (bit-for-bit)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_cluster_winners_segmented_matches_loop(seed):
    """Randomized fleets (empty clusters, ineligible members, continuous
    bids), with and without a tie-break key: the single-lexsort segmented
    implementation must pick bit-identical winner sets to the seed
    per-cluster argsort loop."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(5, 400))
    num_clusters = int(rng.integers(1, 9))
    kj = int(rng.integers(1, 9))
    clusters = rng.integers(0, num_clusters, n)
    if num_clusters > 2:
        clusters[clusters == 1] = 0          # leave cluster 1 empty
    clusters = jnp.asarray(clusters, jnp.int32)
    bids = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
    eligible = jnp.asarray(rng.uniform(size=n) > 0.35)
    tb = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
    for tie in (None, tb):
        w_loop = np.asarray(A.cluster_winners_loop(
            bids, clusters, eligible, kj, num_clusters, tie))
        w_seg = np.asarray(A.cluster_winners(
            bids, clusters, eligible, kj, num_clusters, tie,
            impl="segmented"))
        np.testing.assert_array_equal(w_seg, w_loop)


@pytest.mark.parametrize("seed", [10, 11, 12])
def test_cluster_winners_tie_heavy_matches_loop(seed):
    """Quantized bids and tie-breaks force exact float ties at the K_j
    boundary — both implementations must resolve them identically
    (stable sort order: bid, then tie-break, then client index)."""
    rng = np.random.default_rng(seed)
    n, num_clusters, kj = 120, 5, 4
    clusters = jnp.asarray(rng.integers(0, num_clusters, n), jnp.int32)
    bids = jnp.asarray(rng.choice([0.1, 0.3, 0.3, 0.3, 0.5], n), jnp.float32)
    eligible = jnp.asarray(rng.uniform(size=n) > 0.25)
    tb = jnp.asarray(rng.choice([0.0, 0.2, 0.2, 0.7], n), jnp.float32)
    w_loop = np.asarray(A.cluster_winners_loop(
        bids, clusters, eligible, kj, num_clusters, tb))
    w_seg = np.asarray(A.cluster_winners(
        bids, clusters, eligible, kj, num_clusters, tb))
    np.testing.assert_array_equal(w_seg, w_loop)


def test_select_lowest_bids_lexicographic_tiebreak():
    """Regression (ISSUE 3 satellite): distinct bids closer than the old
    additive 1e-6 epsilon must be ordered by bid alone — the tie-break is
    consulted only on exactly-equal bids."""
    # client 0 has the strictly lowest bid but the *largest* tie-break;
    # the old `bids + 1e-6 * tie` composite key would have flipped it.
    bids = jnp.asarray([0.5, 0.5 + 2e-7, 0.9], jnp.float32)
    assert float(bids[0]) < float(bids[1])           # distinct in f32
    tie = jnp.asarray([1.0, 0.0, 0.0], jnp.float32)
    eligible = jnp.ones((3,), bool)
    win = np.asarray(A.select_lowest_bids(bids, eligible, 1, tie))
    np.testing.assert_array_equal(win, [True, False, False])
    # on exactly-equal bids the tie-break decides
    bids_eq = jnp.asarray([0.5, 0.5, 0.9], jnp.float32)
    tie_eq = jnp.asarray([0.7, 0.2, 0.0], jnp.float32)
    win_eq = np.asarray(A.select_lowest_bids(bids_eq, eligible, 1, tie_eq))
    np.testing.assert_array_equal(win_eq, [False, True, False])


def test_select_lowest_bids_topk_matches_argsort_path():
    """The no-tie-break top_k fast path must equal the sort-based
    definition (lax.top_k prefers lower indices on ties, like a stable
    argsort)."""
    rng = np.random.default_rng(7)
    for _ in range(10):
        n = int(rng.integers(4, 200))
        k = int(rng.integers(1, 12))
        bids = jnp.asarray(rng.choice([0.1, 0.4, 0.4, 0.8], n), jnp.float32)
        eligible = jnp.asarray(rng.uniform(size=n) > 0.3)
        win = np.asarray(A.select_lowest_bids(bids, eligible, k))
        # sort-based reference: zero tie-break == pure stable bid order
        ref = np.asarray(A.select_lowest_bids(
            bids, eligible, k, jnp.zeros((n,), jnp.float32)))
        np.testing.assert_array_equal(win, ref)


# ----------------------------------------------------------------------
# zero-winner reward guards
# ----------------------------------------------------------------------

def test_zero_winner_rewards_are_exactly_zero():
    cfg = FLConfig()
    n = 16
    won = jnp.zeros((n,), bool)
    sizes = jnp.full((n,), 500, jnp.int32)
    bids = jnp.asarray(np.random.default_rng(0).uniform(0, 1, n), jnp.float32)
    r15 = np.asarray(A.reward_sample_share(won, sizes, cfg))
    assert np.all(r15 == 0.0) and np.all(np.isfinite(r15))
    r16, server = A.reward_bid_share(won, bids, cfg)
    assert np.all(np.asarray(r16) == 0.0)
    assert float(server) == 0.0 and np.isfinite(float(server))


def test_depleted_fleet_round_has_no_winners_and_zero_rewards():
    """A fully-depleted fleet (every Cr = inf) is the reachable zero-winner
    round: the fused step must log zero winners, zero rewards, and finite
    metrics — no NaNs."""
    cfg = FLConfig(num_clients=24, num_clusters=4, select_ratio=0.25,
                   scheme="gradient_cluster_auction")
    rng = np.random.default_rng(0)
    state = SEL.SelectionState(
        clusters=jnp.asarray(rng.integers(0, 4, 24), jnp.int32),
        residual=jnp.full((24,), 0.01, jnp.float32),    # can't afford a round
        history=jnp.zeros((24,), jnp.int32),
        local_sizes=jnp.asarray(rng.integers(100, 1200, 24), jnp.int32))
    step = R.make_round_step(cfg)
    _, win, metrics = step(state, jax.random.PRNGKey(0))
    m = jax.device_get(metrics)
    assert not np.asarray(win).any()
    assert int(m["num_winners"]) == 0
    assert float(m["client_reward_sum"]) == 0.0
    assert float(m["server_reward"]) == 0.0
    assert float(m["mean_bid"]) == 0.0
    for v in m.values():
        assert np.all(np.isfinite(np.asarray(v, np.float64)))


# ----------------------------------------------------------------------
# scan path vs seed per-round path
# ----------------------------------------------------------------------

def _make_state(cfg, seed=0):
    return R.synthetic_fleet(cfg, jax.random.PRNGKey(seed))


@pytest.mark.parametrize("scheme", [
    "gradient_cluster_auction", "gradient_cluster_random", "random"])
def test_simulate_rounds_matches_reference(scheme):
    """simulate_rounds (one lax.scan program, segmented winners) vs the
    seed per-round Python path (eager rounds, per-cluster argsort loop):
    bit-identical winner masks, energy trajectories and history under the
    same key stream."""
    cfg = FLConfig(num_clients=60, num_clusters=6, select_ratio=0.2,
                   scheme=scheme, init_energy_mode="normal")
    state = _make_state(cfg, seed=1)
    key = jax.random.PRNGKey(123)
    T = 10
    fs, m, wins = R.simulate_rounds(state, cfg, key, T, record_wins=True)
    fs_r, m_r, wins_r = R.simulate_rounds_reference(state, cfg, key, T,
                                                    record_wins=True)
    np.testing.assert_array_equal(np.asarray(wins), wins_r)
    np.testing.assert_array_equal(np.asarray(fs.residual),
                                  np.asarray(fs_r.residual))
    np.testing.assert_array_equal(np.asarray(fs.history),
                                  np.asarray(fs_r.history))
    # per-round energy trajectory, elementwise-exact; other metrics may
    # differ by float reassociation under fusion (e.g. std) — allclose
    for name in m:
        np.testing.assert_allclose(
            np.asarray(m[name], np.float64),
            np.asarray(m_r[name], np.float64), rtol=1e-5, atol=1e-5,
            err_msg=name)
    np.testing.assert_array_equal(np.asarray(m["num_winners"]),
                                  m_r["num_winners"])


def test_simulate_rounds_metrics_shapes_and_history():
    cfg = FLConfig(num_clients=40, num_clusters=4, select_ratio=0.2,
                   scheme="gradient_cluster_auction",
                   init_energy_mode="normal")
    state = _make_state(cfg)
    T = 7
    fs, m, wins = R.simulate_rounds(state, cfg, jax.random.PRNGKey(5), T,
                                    record_wins=True)
    assert all(np.asarray(v).shape[0] == T for v in m.values())
    assert np.asarray(wins).shape == (T, 40)
    # history counts participation exactly
    np.testing.assert_array_equal(
        np.asarray(fs.history),
        np.asarray(wins).sum(axis=0).astype(np.int32))
    # energy never increases
    assert np.all(np.asarray(fs.residual) <= np.asarray(state.residual))


def test_round_step_matches_eager_pipeline():
    """make_round_step (one jitted program) must reproduce the eager
    select_round -> rewards -> update_after_round pipeline the server ran
    before fusion."""
    cfg = FLConfig(num_clients=50, num_clusters=5, select_ratio=0.2,
                   scheme="gradient_cluster_auction",
                   init_energy_mode="normal")
    state = _make_state(cfg, seed=2)
    key = jax.random.PRNGKey(9)
    step = R.make_round_step(cfg)
    new_state, win, metrics = step(state, key)

    win_e, info = SEL.select_round(state, cfg, key)
    cr, server_r = A.reward_bid_share(win_e, info["bids"], cfg)
    state_e = SEL.update_after_round(state, win_e, cfg)
    np.testing.assert_array_equal(np.asarray(win), np.asarray(win_e))
    np.testing.assert_array_equal(np.asarray(new_state.residual),
                                  np.asarray(state_e.residual))
    np.testing.assert_allclose(float(metrics["client_reward_sum"]),
                               float(cr.sum()), rtol=1e-6)
    np.testing.assert_allclose(float(metrics["server_reward"]),
                               float(server_r), rtol=1e-6)


def test_vds_gap_device_matches_host():
    from repro.core.virtual_dataset import (client_count_histograms,
                                            virtual_dataset_gap,
                                            virtual_dataset_gap_device)
    rng = np.random.default_rng(0)
    n_clients, num_classes = 30, 10
    labels = [rng.integers(0, num_classes, rng.integers(20, 200))
              for _ in range(n_clients)]
    counts = client_count_histograms(labels, num_classes)
    global_hist = np.ones((num_classes,)) / num_classes
    for sel_seed in range(4):
        sel = rng.uniform(size=n_clients) > 0.6
        host = virtual_dataset_gap(labels, sel, global_hist, num_classes)
        dev = float(virtual_dataset_gap_device(
            jnp.asarray(sel), jnp.asarray(counts), jnp.asarray(global_hist)))
        np.testing.assert_allclose(dev, host, atol=1e-6)
    # empty selection falls back to the uniform histogram on both paths
    empty = np.zeros((n_clients,), bool)
    host = virtual_dataset_gap(labels, empty, global_hist, num_classes)
    dev = float(virtual_dataset_gap_device(
        jnp.asarray(empty), jnp.asarray(counts), jnp.asarray(global_hist)))
    np.testing.assert_allclose(dev, host, atol=1e-6)


def test_simulate_rounds_winner_invariants_property():
    """Property test (hypothesis, optional): across simulated rounds every
    winner is eligible (affordable + above s_min) and each cluster stays
    within its K_j cap."""
    pytest.importorskip(
        "hypothesis",
        reason="property tests need the optional hypothesis extra")
    import hypothesis.strategies as st
    from hypothesis import given, settings

    @given(seed=st.integers(0, 100))
    @settings(max_examples=10, deadline=None)
    def run(seed):
        cfg = FLConfig(num_clients=48, num_clusters=4, select_ratio=0.25,
                       scheme="gradient_cluster_auction",
                       init_energy_mode="normal")
        state = _make_state(cfg, seed=seed)
        T = 6
        _, m, wins = R.simulate_rounds(state, cfg, jax.random.PRNGKey(seed),
                                       T, record_wins=True)
        wins = np.asarray(wins)
        clusters = np.asarray(state.clusters)
        kj = SEL.k_per_cluster(cfg)
        sizes = np.asarray(state.local_sizes)
        smin = np.asarray(m["s_min"])
        for t in range(T):
            for j in range(cfg.num_clusters):
                assert wins[t][clusters == j].sum() <= kj
            assert np.all(sizes[wins[t]] >= smin[t])

    run()
