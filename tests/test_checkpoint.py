"""Checkpoint I/O (repro.checkpoint.io) + the server's crash/resume
path: flattened-key collision guard, treedef-drift warning, roundtrip
fidelity, and the bit-exact resume guarantee (a resumed dynamics-free
run walks the remaining rounds identically to an uninterrupted one)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.checkpoint import io as CKPT
from repro.configs.base import FLConfig
from repro.core.adapters import cnn_adapter
from repro.core.server import FederatedServer
from repro.data.partition import partition_clients
from repro.data.synthetic import make_image_dataset

N_CLIENTS = 10
POOL = 700


@pytest.fixture(autouse=True)
def _obs_reset():
    obs.OBS.reset()
    yield
    obs.OBS.reset()


def _cfg(**kw):
    base = dict(num_clients=N_CLIENTS, num_clusters=3, select_ratio=0.4,
                rounds=4, local_epochs=1, sample_window=10,
                cluster_resamples=2, init_energy_mode="normal", seed=3)
    base.update(kw)
    return FLConfig(**base)


@pytest.fixture(scope="module")
def data():
    train, test = make_image_dataset("mnist", n_train=POOL, n_test=120,
                                     seed=3)
    return train, test


def _server(cfg, data):
    train, test = data
    clients = partition_clients(train.y, cfg, seed=3)
    return FederatedServer(cfg, cnn_adapter("mnist"), train.x, train.y,
                          clients, {"x": test.x[:64], "y": test.y[:64]})


def _leaves(params):
    return [np.asarray(x) for x in jax.tree.leaves(params)]


# ----------------------------------------------------------------------
# io-level guards
# ----------------------------------------------------------------------

def test_roundtrip_preserves_values_and_step(tmp_path):
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"inner": jnp.array([1, 2, 3], jnp.int32)}}
    path = str(tmp_path / "ck")
    CKPT.save(path, tree, step=7, extra={"note": 1})
    out, step = CKPT.restore(path, tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["inner"]),
                                  np.asarray(tree["b"]["inner"]))
    assert out["b"]["inner"].dtype == jnp.int32


def test_duplicate_flattened_key_raises(tmp_path):
    # dict nesting "a"/"b" and literal key "a/b" stringify to the same
    # flat path — saving would silently drop one leaf
    tree = {"a": {"b": np.zeros(2)}, "a/b": np.ones(2)}
    with pytest.raises(ValueError, match="duplicate flattened"):
        CKPT.save(str(tmp_path / "dup"), tree)


def test_treedef_drift_warns_but_restores_by_key(tmp_path):
    path = str(tmp_path / "drift")
    CKPT.save(path, {"a": [np.arange(3.0)]})          # list container
    like = {"a": (jnp.zeros(3),)}                     # same keys, tuple
    with pytest.warns(UserWarning, match="treedef mismatch"):
        out, _ = CKPT.restore(path, like)
    np.testing.assert_array_equal(np.asarray(out["a"][0]),
                                  np.arange(3.0))


def test_key_set_mismatch_asserts(tmp_path):
    path = str(tmp_path / "keys")
    CKPT.save(path, {"a": np.zeros(2)})
    with pytest.raises(AssertionError, match="keys mismatch"):
        CKPT.restore(path, {"a": np.zeros(2), "b": np.zeros(2)})


# ----------------------------------------------------------------------
# checkpoint hardening: atomic writes + integrity digest
# ----------------------------------------------------------------------

def test_truncated_snapshot_raises_checkpoint_corrupt(tmp_path):
    import os
    path = str(tmp_path / "trunc")
    tree = {"w": jnp.arange(64, dtype=jnp.float32)}
    CKPT.save(path, tree)
    size = os.path.getsize(path + ".npz")
    with open(path + ".npz", "r+b") as f:
        f.truncate(size // 2)
    with pytest.raises(CKPT.CheckpointCorrupt, match="integrity"):
        CKPT.restore(path, tree)


def test_bitrot_snapshot_raises_checkpoint_corrupt(tmp_path):
    path = str(tmp_path / "rot")
    tree = {"w": jnp.arange(64, dtype=jnp.float32)}
    CKPT.save(path, tree)
    with open(path + ".npz", "r+b") as f:
        f.seek(40)
        f.write(b"\xff\xff\xff\xff")
    with pytest.raises(CKPT.CheckpointCorrupt, match="integrity"):
        CKPT.restore(path, tree)


def test_garbage_manifest_raises_checkpoint_corrupt(tmp_path):
    path = str(tmp_path / "badjson")
    tree = {"w": jnp.arange(4, dtype=jnp.float32)}
    CKPT.save(path, tree)
    with open(path + ".json", "w") as f:
        f.write("{not json")
    with pytest.raises(CKPT.CheckpointCorrupt, match="manifest"):
        CKPT.restore(path, tree)


def test_digestless_manifest_still_restores(tmp_path):
    # pre-hardening manifests carry no digest: they must keep loading
    import json
    path = str(tmp_path / "legacy")
    tree = {"w": jnp.arange(4, dtype=jnp.float32)}
    CKPT.save(path, tree)
    with open(path + ".json") as f:
        manifest = json.load(f)
    del manifest["digest"]
    with open(path + ".json", "w") as f:
        json.dump(manifest, f)
    out, _ = CKPT.restore(path, tree)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


def test_save_leaves_no_tmp_files(tmp_path):
    import os
    path = str(tmp_path / "atomic")
    CKPT.save(path, {"w": jnp.zeros(3)})
    names = sorted(os.listdir(tmp_path))
    assert names == ["atomic.json", "atomic.npz"]


# ----------------------------------------------------------------------
# server crash/resume
# ----------------------------------------------------------------------

def test_resume_is_bit_exact_vs_uninterrupted(data, tmp_path):
    cfg = _cfg(rounds=4)
    ref = _server(cfg, data)
    logs_ref = ref.run(rounds=4)

    # "crash" after round 2: checkpoint_every=2 saves at the t=1
    # boundary; the run continues to round 2 and is then abandoned
    path = str(tmp_path / "resume_ck")
    crashed = _server(cfg, data)
    crashed.run(rounds=3, checkpoint_every=2, checkpoint_path=path)

    resumed = _server(cfg, data)
    logs_res = resumed.run(rounds=4, checkpoint_path=path, resume=True)

    # resumed run starts at round 2 and matches the uninterrupted run's
    # tail bit-for-bit: params, selections, history, reward tally
    assert [l.round for l in logs_res] == [2, 3]
    for x, y in zip(_leaves(ref.params), _leaves(resumed.params)):
        np.testing.assert_array_equal(x, y)
    for lr_, lv in zip(logs_ref[2:], logs_res):
        np.testing.assert_array_equal(lr_.selected, lv.selected)
        assert lr_.mean_bid == lv.mean_bid
        assert lr_.test_acc == pytest.approx(lv.test_acc, nan_ok=True)
    np.testing.assert_array_equal(ref._host_history,
                                  resumed._host_history)
    assert ref.total_client_reward == pytest.approx(
        resumed.total_client_reward)


def test_resume_with_dynamics_and_defense_state(data, tmp_path):
    # the harder tree: dynamics avail/key + host rng chain + defense
    # clip_state/strikes must all survive the crash boundary
    cfg = _cfg(rounds=4, churn=0.2, deadline=1.1, adversary_frac=0.3,
               attack="nan", defense="median")
    ref = _server(cfg, data)
    ref.run(rounds=4)

    path = str(tmp_path / "dyn_ck")
    crashed = _server(cfg, data)
    crashed.run(rounds=3, checkpoint_every=2, checkpoint_path=path)
    resumed = _server(cfg, data)
    resumed.run(rounds=4, checkpoint_path=path, resume=True)

    for x, y in zip(_leaves(ref.params), _leaves(resumed.params)):
        np.testing.assert_array_equal(x, y)
    np.testing.assert_array_equal(
        np.asarray(obs.device_get(ref.state.strikes)),
        np.asarray(obs.device_get(resumed.state.strikes)))
    np.testing.assert_array_equal(
        np.asarray(obs.device_get(ref.dyn_state.avail)),
        np.asarray(obs.device_get(resumed.dyn_state.avail)))


def test_resume_scheme_mismatch_raises(data, tmp_path):
    # the manifest records the active selection scheme; resuming under a
    # different --scheme-select fails loudly instead of silently
    # diverging (the checkpointed scheme_state and key chain are
    # scheme-shaped)
    path = str(tmp_path / "mismatch_ck")
    srv = _server(_cfg(rounds=4), data)              # scheme_select=paper
    srv.run(rounds=3, checkpoint_every=2, checkpoint_path=path)
    other = _server(_cfg(rounds=4, scheme_select="longterm_auction"), data)
    with pytest.raises(ValueError, match="--scheme-select"):
        other.run(rounds=4, checkpoint_path=path, resume=True)


def test_resume_bit_exact_with_longterm_scheme_state(data, tmp_path):
    # the budget/payment ledger (SelectionState.scheme_state) must ride
    # the checkpoint: a resumed long-term-auction run walks the
    # remaining rounds bit-identically to an uninterrupted one
    cfg = _cfg(rounds=4, scheme_select="longterm_auction")
    ref = _server(cfg, data)
    ref.run(rounds=4)

    path = str(tmp_path / "longterm_ck")
    crashed = _server(cfg, data)
    crashed.run(rounds=3, checkpoint_every=2, checkpoint_path=path)
    resumed = _server(cfg, data)
    logs_res = resumed.run(rounds=4, checkpoint_path=path, resume=True)

    assert [l.round for l in logs_res] == [2, 3]
    for x, y in zip(_leaves(ref.params), _leaves(resumed.params)):
        np.testing.assert_array_equal(x, y)
    a, b = ref.state.scheme_state, resumed.state.scheme_state
    np.testing.assert_array_equal(np.asarray(obs.device_get(a.spent)),
                                  np.asarray(obs.device_get(b.spent)))
    np.testing.assert_array_equal(np.asarray(obs.device_get(a.queue)),
                                  np.asarray(obs.device_get(b.queue)))
    np.testing.assert_array_equal(np.asarray(obs.device_get(a.paid)),
                                  np.asarray(obs.device_get(b.paid)))


def test_no_checkpoint_written_when_disabled(data, tmp_path):
    path = str(tmp_path / "never")
    srv = _server(_cfg(rounds=2), data)
    srv.run(rounds=2, checkpoint_path=path)   # checkpoint_every=0
    import os
    assert not os.path.exists(path + ".npz")
