"""Clustering tests: sample window, gradient features, k-means behaviour,
and the paper's core claim that gradient clustering groups clients by local
distribution under imbalance."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need the optional hypothesis extra")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs.base import FLConfig
from repro.core import clustering as CL


def test_window_indices_bounds():
    idx = CL.window_indices(jax.random.PRNGKey(0), 17, 50)
    assert idx.shape == (50,)
    assert int(idx.min()) >= 0 and int(idx.max()) < 17


@given(k=st.integers(2, 6), n_per=st.integers(10, 30),
       sep=st.floats(5.0, 20.0))
@settings(max_examples=15, deadline=None)
def test_kmeans_separated_blobs(k, n_per, sep):
    rng = np.random.default_rng(int(sep * 10) + k)
    centers = rng.normal(size=(k, 8)) * sep
    pts = np.concatenate([c + 0.1 * rng.normal(size=(n_per, 8))
                          for c in centers])
    labels, cent = CL.kmeans(jnp.asarray(pts, jnp.float32), k,
                             jax.random.PRNGKey(0))
    lab = np.asarray(labels).reshape(k, n_per)
    # every blob lands in exactly one cluster
    for g in range(k):
        assert len(np.unique(lab[g])) == 1
    assert len(np.unique(lab[:, 0])) == k


def test_kmeans_labels_in_range():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(40, 4)),
                    jnp.float32)
    labels, cent = CL.kmeans(x, 5, jax.random.PRNGKey(1))
    assert labels.shape == (40,)
    assert int(labels.min()) >= 0 and int(labels.max()) < 5
    assert cent.shape == (5, 4)


def test_gradient_clustering_groups_clients_by_label():
    """The paper's §III-C claim: with the sample window, gradient features
    of same-label clients cluster together even when local sizes differ by
    an order of magnitude."""
    from repro.core.adapters import cnn_adapter
    from repro.data.synthetic import make_image_dataset

    train, _ = make_image_dataset("mnist", n_train=2000, n_test=100)
    cfg = FLConfig(num_clients=12, num_clusters=4, sample_window=30,
                   cluster_resamples=3, num_classes=10)
    adapter = cnn_adapter("mnist")
    params = adapter.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # 12 clients over 4 labels, sizes 40..400 (heavy imbalance)
    data = []
    true = []
    for i in range(12):
        lab = i % 4
        size = int(rng.integers(40, 400))
        idx = rng.choice(np.nonzero(train.y == lab)[0], size)
        data.append((train.x[idx], train.y[idx]))
        true.append(lab)

    labels, cent, feats = CL.cluster_clients(
        adapter.grad, params, data, cfg, jax.random.PRNGKey(1))
    lab = np.asarray(labels)
    # same-label clients must share a cluster; different labels must not.
    for a in range(12):
        for b in range(12):
            if true[a] == true[b]:
                assert lab[a] == lab[b], (a, b, lab)
            else:
                assert lab[a] != lab[b], (a, b, lab)


def _blobs(k, n_per, f=16, sep=10.0, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, f)) * sep
    return jnp.asarray(np.concatenate(
        [c + 0.5 * rng.normal(size=(n_per, f)) for c in centers]),
        jnp.float32)


@pytest.mark.parametrize("k", [3, 6])
def test_incremental_kmeanspp_matches_scan(k):
    """The incremental seeding (running min-distance, O(N·F) per pick)
    must reproduce the scan version's (N, K, F)-broadcast picks exactly —
    same key stream, same per-centroid distance math."""
    feats = jax.random.normal(jax.random.PRNGKey(5), (200, 8))
    a = CL._kmeanspp_init(feats, k, jax.random.PRNGKey(3))
    b = CL._kmeanspp_init_scan(feats, k, jax.random.PRNGKey(3))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("restarts", [1, 3])
def test_batched_kmeans_matches_reference_run_for_run(restarts):
    """The vmapped batched-restart engine must reproduce the per-restart
    Python-loop reference (same fold_in key stream, same tie rule)."""
    pts = _blobs(4, 60)
    key = jax.random.PRNGKey(7)
    lab_b, cent_b = CL.kmeans(pts, 4, key, restarts=restarts)
    lab_r, cent_r = CL.kmeans_reference(pts, 4, key, restarts=restarts)
    np.testing.assert_array_equal(np.asarray(lab_b), np.asarray(lab_r))
    np.testing.assert_allclose(np.asarray(cent_b), np.asarray(cent_r),
                               rtol=1e-4, atol=1e-4)


def test_kmeans_impls_agree():
    """ref (naive broadcast) and the fused auto path pick the same
    clusters on separated data."""
    pts = _blobs(5, 40)
    key = jax.random.PRNGKey(2)
    lab_a, _ = CL.kmeans(pts, 5, key, impl="auto")
    lab_r, _ = CL.kmeans(pts, 5, key, impl="ref")
    np.testing.assert_array_equal(np.asarray(lab_a), np.asarray(lab_r))


def test_blocked_projection_separation_and_determinism():
    """Column-blocked JL projection: deterministic under a fixed key,
    shape-correct even when in_dim is not a block multiple, and
    separation-preserving like the dense projection."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(20, 5000)) + 5, jnp.float32)
    b = jnp.asarray(rng.normal(size=(20, 5000)) - 5, jnp.float32)
    key = jax.random.PRNGKey(0)
    ap = CL.project_features_blocked(key, a, 64, block=1024)
    bp = CL.project_features_blocked(key, b, 64, block=1024)
    assert ap.shape == (20, 64)
    np.testing.assert_array_equal(
        np.asarray(ap),
        np.asarray(CL.project_features_blocked(key, a, 64, block=1024)))
    da = float(jnp.linalg.norm(ap.mean(0) - bp.mean(0)))
    within = float(jnp.std(ap)) + float(jnp.std(bp))
    assert da > within


def test_random_projection_preserves_separation():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(20, 2000)) + 5
    b = rng.normal(size=(20, 2000)) - 5
    proj = CL.random_projection(jax.random.PRNGKey(0), 2000, 64)
    ap, bp = jnp.asarray(a) @ proj, jnp.asarray(b) @ proj
    da = float(jnp.linalg.norm(ap.mean(0) - bp.mean(0)))
    within = float(jnp.std(ap)) + float(jnp.std(bp))
    assert da > within          # classes remain separated after projection
