"""End-to-end behaviour tests for the paper's system: the full FL loop
(cluster -> auction -> local train -> aggregate) and the paper's headline
claims at reduced scale."""
import jax
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core.adapters import cnn_adapter, transformer_adapter
from repro.core.server import FederatedServer
from repro.data.partition import partition_clients
from repro.data.synthetic import make_image_dataset, make_token_dataset


def _make_server(scheme, rounds=4, nu=1.0, aggregator="fedavg", seed=0,
                 n_clients=16, n_clusters=4):
    cfg = FLConfig(num_clients=n_clients, num_clusters=n_clusters,
                   select_ratio=0.25, rounds=rounds, non_iid_level=nu,
                   scheme=scheme, aggregator=aggregator,
                   init_energy_mode="normal", sample_window=20,
                   cluster_resamples=2, seed=seed)
    train, test = make_image_dataset("mnist", n_train=1600, n_test=300,
                                     seed=seed)
    clients = partition_clients(train.y, cfg, seed=seed)
    return FederatedServer(cfg, cnn_adapter("mnist"), train.x, train.y,
                           clients, {"x": test.x, "y": test.y}), cfg


@pytest.mark.parametrize("scheme", [
    "gradient_cluster_auction", "gradient_cluster_random", "random"])
def test_fl_round_loop_runs(scheme):
    srv, cfg = _make_server(scheme, rounds=3)
    logs = srv.run()
    assert len(logs) == 3
    for log in logs:
        assert np.isfinite(log.test_acc) and np.isfinite(log.test_loss)
        assert 1 <= len(log.selected) <= 8
        assert log.energy_std >= 0
    # energy monotonically consumed for participants
    assert float(srv.state.residual.max()) <= 100.0
    assert int(srv.state.history.sum()) == sum(len(l.selected) for l in logs)


def test_fedprox_aggregator_runs():
    srv, cfg = _make_server("gradient_cluster_auction", rounds=2,
                            aggregator="fedprox")
    logs = srv.run()
    assert len(logs) == 2 and np.isfinite(logs[-1].test_loss)


def test_clustering_is_by_primary_label():
    """Stage-1 on the real pipeline: clients sharing a primary label end up
    in the same cluster (nu=1, imbalanced sizes)."""
    srv, cfg = _make_server("gradient_cluster_random", rounds=1,
                            n_clients=8, n_clusters=4)
    srv.cluster()
    clusters = np.asarray(srv.state.clusters)
    primaries = np.array([c.primary_label for c in srv.clients])
    for a in range(len(primaries)):
        for b in range(len(primaries)):
            if primaries[a] == primaries[b]:
                assert clusters[a] == clusters[b]


def test_cluster_selection_reduces_vds_gap():
    """§III-B: the virtual dataset of cluster-based rounds is closer to the
    global distribution than random selection's."""
    srv_c, _ = _make_server("gradient_cluster_random", rounds=4, seed=1)
    srv_r, _ = _make_server("random", rounds=4, seed=1)
    gap_c = np.mean([l.vds_gap for l in srv_c.run()])
    gap_r = np.mean([l.vds_gap for l in srv_r.run()])
    assert gap_c <= gap_r + 0.05


def test_auction_energy_balance_headline():
    """Fig 9/10 at reduced scale: auction yields a more balanced fleet than
    random selection after the same number of rounds."""
    srv_a, _ = _make_server("gradient_cluster_auction", rounds=6, seed=2)
    srv_r, _ = _make_server("random", rounds=6, seed=2)
    std_a = srv_a.run()[-1].energy_std
    std_r = srv_r.run()[-1].energy_std
    assert std_a <= std_r * 1.15


def test_transformer_fl_loop():
    """The selection layer is model-agnostic: FL rounds over a reduced
    registry transformer."""
    from repro.configs.registry import get_smoke_config
    mcfg = get_smoke_config("qwen2-0.5b")
    cfg = FLConfig(num_clients=8, num_clusters=2, select_ratio=0.25,
                   rounds=2, lr=0.1, non_iid_level=1.0,
                   scheme="gradient_cluster_auction", num_classes=4,
                   sample_window=6, cluster_resamples=2)
    toks, topics = make_token_dataset(num_topics=4, vocab=mcfg.vocab_size,
                                      seq_len=16, n=240, seed=0)
    clients = partition_clients(topics, cfg, seed=0)
    srv = FederatedServer(cfg, transformer_adapter(mcfg), toks, topics,
                          clients, {"x": toks[:32], "y": topics[:32]})
    logs = srv.run()
    assert len(logs) == 2
    assert np.isfinite(logs[-1].test_loss)


def test_checkpointing_server_params():
    import os
    import tempfile

    from repro.checkpoint.io import restore, save
    srv, cfg = _make_server("random", rounds=1)
    srv.run()
    with tempfile.TemporaryDirectory() as d:
        save(os.path.join(d, "fl"), srv.params, step=1)
        got, step = restore(os.path.join(d, "fl"), srv.params)
        for a, b in zip(jax.tree.leaves(srv.params), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
