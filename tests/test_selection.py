"""Selection-scheme tests: Algorithm 1 invariants across all four schemes."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need the optional hypothesis extra")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs.base import FLConfig
from repro.core import selection as SEL
from repro.core import energy as EN


def make_state(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return SEL.SelectionState(
        clusters=jnp.asarray(rng.integers(0, cfg.num_clusters,
                                          cfg.num_clients), jnp.int32),
        residual=jnp.asarray(rng.uniform(50, 100, cfg.num_clients),
                             jnp.float32),
        history=jnp.zeros((cfg.num_clients,), jnp.int32),
        local_sizes=jnp.asarray(rng.integers(100, 1200, cfg.num_clients),
                                jnp.int32),
    )


@pytest.mark.parametrize("scheme", [
    "random", "gradient_cluster_random", "weights_cluster_random",
    "gradient_cluster_auction"])
def test_selection_count_and_mask(scheme):
    cfg = FLConfig(num_clients=50, num_clusters=5, select_ratio=0.2,
                   scheme=scheme)
    state = make_state(cfg)
    win, info = SEL.select_round(state, cfg, jax.random.PRNGKey(0))
    w = np.asarray(win)
    assert w.dtype == bool and w.shape == (50,)
    assert 1 <= w.sum() <= 10 + cfg.num_clusters  # K total (clusters may pad)


@given(seed=st.integers(0, 200))
@settings(max_examples=25, deadline=None)
def test_auction_winners_satisfy_threshold(seed):
    cfg = FLConfig(num_clients=40, num_clusters=4, select_ratio=0.25,
                   scheme="gradient_cluster_auction")
    state = make_state(cfg, seed)
    win, info = SEL.select_round(state, cfg, jax.random.PRNGKey(seed))
    w = np.asarray(win)
    sizes = np.asarray(state.local_sizes)
    smin = int(info["s_min"])
    assert np.all(sizes[w] >= smin)          # sample-threshold gate
    # per-cluster winner cap
    kj = SEL.k_per_cluster(cfg)
    cl = np.asarray(state.clusters)
    for j in range(cfg.num_clusters):
        assert w[cl == j].sum() <= kj


def test_energy_update_only_hits_selected():
    cfg = FLConfig(num_clients=30, num_clusters=3,
                   scheme="gradient_cluster_auction")
    state = make_state(cfg)
    win, _ = SEL.select_round(state, cfg, jax.random.PRNGKey(1))
    new = SEL.update_after_round(state, win, cfg)
    w = np.asarray(win)
    before, after = np.asarray(state.residual), np.asarray(new.residual)
    assert np.all(after[~w] == before[~w])
    assert np.all(after[w] < before[w])
    assert np.all(np.asarray(new.history) ==
                  np.asarray(state.history) + w.astype(np.int32))


def test_depleted_clients_not_selected():
    """Clients that cannot afford the round (Cr = inf) never win the
    auction."""
    cfg = FLConfig(num_clients=20, num_clusters=2, select_ratio=0.5,
                   scheme="gradient_cluster_auction")
    state = make_state(cfg)
    dead = np.zeros(20, bool)
    dead[:10] = True
    residual = np.asarray(state.residual).copy()
    residual[dead] = 0.01          # cannot afford any round
    state = SEL.SelectionState(state.clusters,
                               jnp.asarray(residual), state.history,
                               state.local_sizes)
    win, _ = SEL.select_round(state, cfg, jax.random.PRNGKey(2))
    assert not np.any(np.asarray(win)[dead])


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_per_cluster_matches_loop_oracle(seed):
    """The segmented-rank pass must pick identical winner sets to the
    per-cluster argsort loop under a fixed key — including clusters with
    no eligible member (relaxation) and empty clusters."""
    cfg = FLConfig(num_clients=57, num_clusters=6, select_ratio=0.2,
                   scheme="gradient_cluster_random")
    rng = np.random.default_rng(seed)
    clusters = rng.integers(0, 6, 57)
    clusters[clusters == 4] = 0           # leave cluster 4 empty
    state = SEL.SelectionState(
        clusters=jnp.asarray(clusters, jnp.int32),
        residual=jnp.asarray(rng.uniform(50, 100, 57), jnp.float32),
        history=jnp.zeros((57,), jnp.int32),
        local_sizes=jnp.asarray(rng.integers(100, 1200, 57), jnp.int32))
    eligible = jnp.asarray((rng.uniform(size=57) > 0.4)
                           & (clusters != 2))  # cluster 2: none eligible
    key = jax.random.PRNGKey(seed)
    fast = SEL._random_per_cluster(key, state, cfg, eligible)
    oracle = SEL._random_per_cluster_loop(key, state, cfg, eligible)
    np.testing.assert_array_equal(np.asarray(fast), np.asarray(oracle))


def test_auction_balances_energy_vs_random():
    """The paper's headline claim (Fig 9/10): auction-based selection yields
    lower residual-energy std than random selection. Simulated without
    model training (selection + energy dynamics only)."""
    def run(scheme, rounds=60, seed=3):
        cfg = FLConfig(num_clients=60, num_clusters=6, select_ratio=0.2,
                       scheme=scheme, init_energy_mode="normal")
        state = make_state(cfg, seed)
        key = jax.random.PRNGKey(seed)
        for t in range(rounds):
            key, k = jax.random.split(key)
            win, _ = SEL.select_round(state, cfg, k)
            state = SEL.update_after_round(state, win, cfg)
        return float(EN.energy_balance(state.residual))

    stds_auction = [run("gradient_cluster_auction", seed=s) for s in range(3)]
    stds_random = [run("random", seed=s) for s in range(3)]
    assert np.mean(stds_auction) < np.mean(stds_random) * 1.05
