"""Fleet dynamics (repro.sim.dynamics + the server's degraded
aggregation paths): fault-model semantics, the dedicated PRNG stream's
churn-0 bit-identity guarantee, cross-runtime outcome equivalence, the
buffered-aggregation oracle boundary, and the zero-survivor guard
(params pass through, ``round/empty`` logged, never a NaN)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs.base import FLConfig
from repro.core import rounds as RND
from repro.core import selection as SEL
from repro.core.adapters import cnn_adapter
from repro.core.server import FederatedServer
from repro.data.partition import partition_clients
from repro.data.synthetic import make_image_dataset
from repro.obs.schema import load_jsonl, validate_events
from repro.sim import dynamics as DYN

RUNTIMES = ("sequential", "vectorized", "sharded", "device")
N_CLIENTS = 10
POOL = 700


@pytest.fixture(autouse=True)
def _obs_reset():
    obs.OBS.reset()
    yield
    obs.OBS.reset()


def _cfg(**kw):
    base = dict(num_clients=N_CLIENTS, num_clusters=3, select_ratio=0.4,
                rounds=3, local_epochs=1, sample_window=10,
                cluster_resamples=2, init_energy_mode="normal", seed=3)
    base.update(kw)
    return FLConfig(**base)


@pytest.fixture(scope="module")
def data():
    train, test = make_image_dataset("mnist", n_train=POOL, n_test=120,
                                     seed=3)
    return train, test


def _server(cfg, data):
    train, test = data
    clients = partition_clients(train.y, cfg, seed=3)
    return FederatedServer(cfg, cnn_adapter("mnist"), train.x, train.y,
                           clients, {"x": test.x[:64], "y": test.y[:64]})


def _leaves(params):
    return [np.asarray(x) for x in jax.tree.leaves(params)]


def _assert_trees_equal(a, b):
    for x, y in zip(_leaves(a), _leaves(b)):
        np.testing.assert_array_equal(x, y)


# ----------------------------------------------------------------------
# fault model unit semantics
# ----------------------------------------------------------------------

def _fleet_arrays(n):
    win = jnp.zeros((n,), bool).at[jnp.arange(0, n, 2)].set(True)
    avail = jnp.ones((n,), bool)
    residual = jnp.linspace(10.0, 100.0, n).astype(jnp.float32)
    sizes = jnp.full((n,), 50, jnp.int32)
    return win, avail, residual, sizes


def test_fault_step_deterministic_and_well_formed():
    cfg = _cfg(churn=0.3, deadline=1.2)
    win, avail, residual, sizes = _fleet_arrays(cfg.num_clients)
    key = jax.random.PRNGKey(5)
    out1, lat1, av1 = DYN.fault_step(cfg, key, win, avail, residual, sizes)
    out2, lat2, av2 = DYN.fault_step(cfg, key, win, avail, residual, sizes)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(lat1), np.asarray(lat2))
    np.testing.assert_array_equal(np.asarray(av1), np.asarray(av2))
    out, w = np.asarray(out1), np.asarray(win)
    assert (out[~w] == DYN.NOT_SELECTED).all()
    assert set(np.unique(out[w])) <= {DYN.COMPLETED, DYN.LATE, DYN.DROPPED}
    assert np.isfinite(np.asarray(lat1)).all() and (np.asarray(lat1) > 0).all()


def test_fault_step_no_faults_with_knobs_off():
    # churn 0 + no deadline: every winner completes, nobody churns out
    cfg = _cfg(churn=0.0, deadline=0.0)
    win, avail, residual, sizes = _fleet_arrays(cfg.num_clients)
    out, _, av = DYN.fault_step(cfg, jax.random.PRNGKey(1), win, avail,
                                residual, sizes)
    assert (np.asarray(out)[np.asarray(win)] == DYN.COMPLETED).all()
    assert np.asarray(av).all()


def test_fault_step_tiny_deadline_tags_every_winner_late():
    # 'none' profile: latency = compute + 0.05 > 1e-6 for everyone
    cfg = _cfg(churn=0.0, deadline=1e-6, straggler_profile="none")
    win, avail, residual, sizes = _fleet_arrays(cfg.num_clients)
    out, _, _ = DYN.fault_step(cfg, jax.random.PRNGKey(1), win, avail,
                               residual, sizes)
    assert (np.asarray(out)[np.asarray(win)] == DYN.LATE).all()


def test_staleness_counter_and_weight():
    stale = jnp.asarray([0, 2, 5], jnp.int32)
    out = jnp.asarray([DYN.COMPLETED, DYN.LATE, DYN.NOT_SELECTED],
                      jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(DYN.update_staleness(stale, out)), [0, 3, 6])
    cfg = _cfg(churn=0.1, staleness_alpha=0.5)
    assert DYN.staleness_weight(cfg, 0) == 1.0
    assert abs(DYN.staleness_weight(cfg, 3) - 0.5) < 1e-12


# ----------------------------------------------------------------------
# availability gating in selection
# ----------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ["gradient_cluster_auction",
                                    "gradient_cluster_random"])
def test_select_round_avail_none_equals_all_ones(scheme):
    cfg = _cfg(scheme=scheme, num_clients=40, num_clusters=4)
    state = RND.synthetic_fleet(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(7)
    w_none, _ = SEL.select_round(state, cfg, key)
    w_ones, _ = SEL.select_round(state, cfg, key,
                                 avail=jnp.ones((40,), bool))
    np.testing.assert_array_equal(np.asarray(w_none), np.asarray(w_ones))


def test_select_round_offline_clients_cannot_win():
    cfg = _cfg(scheme="gradient_cluster_auction", num_clients=40,
               num_clusters=4)
    state = RND.synthetic_fleet(cfg, jax.random.PRNGKey(0))
    avail = jnp.arange(40) % 2 == 0        # odd ids offline
    win, _ = SEL.select_round(state, cfg, jax.random.PRNGKey(7),
                              avail=avail)
    assert not bool((np.asarray(win) & ~np.asarray(avail)).any())


# ----------------------------------------------------------------------
# churn-0 regression: the dedicated dynamics key stream must leave
# dynamics-free runs bit-identical (selection logs AND params)
# ----------------------------------------------------------------------

def test_churn_zero_bit_identical_to_plain_config(data):
    cfg_plain = _cfg()
    # every dynamics knob changed EXCEPT churn/deadline (both 0): the
    # run must not see any of it — same code path, same key stream
    cfg_dyn0 = _cfg(churn=0.0, deadline=0.0,
                    straggler_profile="lognormal",
                    aggregation="buffered", buffer_goal=2,
                    staleness_alpha=1.0)
    assert not cfg_dyn0.dynamics_enabled
    sa, sb = _server(cfg_plain, data), _server(cfg_dyn0, data)
    la, lb = sa.run(), sb.run()
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(x.selected, y.selected)
        assert x.mean_bid == y.mean_bid
        assert x.energy_std == y.energy_std
    _assert_trees_equal(sa.params, sb.params)


# ----------------------------------------------------------------------
# cross-runtime equivalence under churn
# ----------------------------------------------------------------------

def test_outcome_masks_identical_across_runtimes(data):
    outs, sels, params = {}, {}, {}
    for rt in RUNTIMES:
        cfg = _cfg(runtime=rt, churn=0.25, deadline=1.2,
                   aggregation="buffered", buffer_goal=2)
        srv = _server(cfg, data)
        srv.run()
        outs[rt] = [o.tolist() for o in srv.outcome_log]
        sels[rt] = [l.selected.tolist() for l in srv.logs]
        params[rt] = srv.params
        for leaf in _leaves(srv.params):
            assert np.isfinite(leaf).all(), rt
    for rt in RUNTIMES[1:]:
        assert outs[rt] == outs["sequential"], rt
        assert sels[rt] == sels["sequential"], rt


def test_buffered_without_faults_matches_sync_oracle(data):
    # deadline huge + churn 0: dynamics path is ON but fault-free, so
    # every winner COMPLETES and the buffered server must walk the exact
    # synchronous-oracle trajectory (selections and params bit-equal —
    # the dedicated dyn key stream never touches the selection chain)
    cfg_sync = _cfg()
    cfg_buf = _cfg(churn=0.0, deadline=1e9, aggregation="buffered")
    assert cfg_buf.dynamics_enabled
    sa, sb = _server(cfg_sync, data), _server(cfg_buf, data)
    la, lb = sa.run(), sb.run()
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(x.selected, y.selected)
    assert all((o == DYN.COMPLETED).all() for o in sb.outcome_log)
    for x, y in zip(_leaves(sa.params), _leaves(sb.params)):
        np.testing.assert_allclose(x, y, rtol=2e-5, atol=2e-6)


# ----------------------------------------------------------------------
# zero-survivor guard + buffered fold events
# ----------------------------------------------------------------------

def test_zero_survivor_rounds_pass_params_through(data):
    mem = obs.configure(memory=True)
    cfg = _cfg(churn=1.0, rejoin_prob=0.0, replace_dropped=False)
    srv = _server(cfg, data)
    p0 = _leaves(srv.params)
    logs = srv.run()
    # every winner dropped every round: params untouched, never NaN
    for x, y in zip(p0, _leaves(srv.params)):
        np.testing.assert_array_equal(x, y)
    assert obs.OBS.counters.get("round/empty", 0) == cfg.rounds
    names = [e.get("name") for e in mem.events if e["kind"] == "dynamics"]
    assert names.count("round/empty") == cfg.rounds
    assert all(np.isfinite(l.test_acc) for l in logs
               if l.round % cfg.eval_every == 0)
    # nobody completed, so everyone's staleness aged one per round
    assert int(jnp.min(srv.state.staleness)) == cfg.rounds


def test_buffered_folds_and_schema_valid_log(data, tmp_path):
    path = str(tmp_path / "events.jsonl")
    mem = obs.configure(jsonl=path, memory=True)
    cfg = _cfg(churn=0.2, deadline=0.8, aggregation="buffered",
               buffer_goal=1, rounds=4)
    srv = _server(cfg, data)
    srv.run()
    for leaf in _leaves(srv.params):
        assert np.isfinite(leaf).all()
    codes = np.concatenate(srv.outcome_log)
    assert (codes == DYN.LATE).any()       # the tight deadline bites
    folds = [e for e in mem.events
             if e["kind"] == "dynamics" and e.get("name") == "buffer/fold"]
    assert folds and all(f["entries"] >= 1 for f in folds)
    errs = validate_events(load_jsonl(path), rounds=4, eval_every=1)
    assert errs == []


# ----------------------------------------------------------------------
# straggler-profile x aggregation-mode sweep (schema-valid, finite)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("profile", DYN.STRAGGLER_PROFILES)
@pytest.mark.parametrize("aggregation", ("sync", "buffered"))
def test_profile_aggregation_sweep_schema_valid(profile, aggregation,
                                                data, tmp_path):
    """Every latency profile composes with both aggregation modes: the
    run completes, params stay finite, and the event log validates."""
    path = str(tmp_path / "events.jsonl")
    obs.configure(jsonl=path, memory=True)
    cfg = _cfg(churn=0.2, deadline=0.9, rounds=3,
               straggler_profile=profile, aggregation=aggregation,
               buffer_goal=1)
    srv = _server(cfg, data)
    logs = srv.run()
    assert len(logs) == 3
    for leaf in _leaves(srv.params):
        assert np.isfinite(leaf).all()
    codes = np.concatenate(srv.outcome_log)
    assert set(np.unique(codes)) <= {DYN.NOT_SELECTED, DYN.COMPLETED,
                                     DYN.LATE, DYN.DROPPED}
    assert validate_events(load_jsonl(path), rounds=3, eval_every=1) == []


# ----------------------------------------------------------------------
# property test: fault_step key-reuse determinism (hypothesis)
# ----------------------------------------------------------------------

def test_fault_step_key_reuse_is_deterministic_property():
    """Property test (hypothesis, optional): fault_step is a pure
    function of (cfg, key, fleet arrays) — calling it twice with the
    same key yields bit-identical outcomes for arbitrary seeds, churn
    rates, deadlines and fleet sizes.  This key-reuse determinism is
    the property behind the buffered==sync oracle and the crash/resume
    bit-exactness guarantee (tests/test_checkpoint.py)."""
    pytest.importorskip(
        "hypothesis",
        reason="property tests need the optional hypothesis extra")
    import hypothesis.strategies as st
    from hypothesis import given, settings

    @given(seed=st.integers(0, 2 ** 31 - 1),
           churn=st.floats(0.0, 0.5),
           deadline=st.floats(0.1, 3.0),
           n=st.integers(4, 24))
    @settings(max_examples=20, deadline=None)
    def run(seed, churn, deadline, n):
        cfg = _cfg(num_clients=n, num_clusters=2, churn=churn,
                   deadline=deadline)
        win, avail, residual, sizes = _fleet_arrays(n)
        key = jax.random.PRNGKey(seed)
        a = DYN.fault_step(cfg, key, win, avail, residual, sizes)
        b = DYN.fault_step(cfg, key, win, avail, residual, sizes)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        out = np.asarray(a[0])
        assert (out[~np.asarray(win)] == DYN.NOT_SELECTED).all()

    run()
