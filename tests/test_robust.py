"""Byzantine-tolerant aggregation (repro.core.aggregation +
repro.sim.dynamics corruption model + the auction reputation loop):
attack semantics, screened-FedAvg estimator correctness, the
defense-off bit-equality boundary, cross-runtime quarantine
equivalence, strike-driven auction bans, and the device warm loop's
zero-retrace guarantee with defenses on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs.base import FLConfig
from repro.core import aggregation as AGG
from repro.core.adapters import cnn_adapter
from repro.core.server import FederatedServer
from repro.data.partition import partition_clients
from repro.data.synthetic import make_image_dataset
from repro.obs.schema import load_jsonl, validate_events
from repro.sim import dynamics as DYN

RUNTIMES = ("sequential", "vectorized", "sharded", "device")
N_CLIENTS = 10
POOL = 700


@pytest.fixture(autouse=True)
def _obs_reset():
    obs.OBS.reset()
    yield
    obs.OBS.reset()


def _cfg(**kw):
    base = dict(num_clients=N_CLIENTS, num_clusters=3, select_ratio=0.4,
                rounds=3, local_epochs=1, sample_window=10,
                cluster_resamples=2, init_energy_mode="normal", seed=3)
    base.update(kw)
    return FLConfig(**base)


@pytest.fixture(scope="module")
def data():
    train, test = make_image_dataset("mnist", n_train=POOL, n_test=120,
                                     seed=3)
    return train, test


def _server(cfg, data):
    train, test = data
    clients = partition_clients(train.y, cfg, seed=3)
    return FederatedServer(cfg, cnn_adapter("mnist"), train.x, train.y,
                           clients, {"x": test.x[:64], "y": test.y[:64]})


def _leaves(params):
    return [np.asarray(x) for x in jax.tree.leaves(params)]


def _assert_trees_equal(a, b):
    for x, y in zip(_leaves(a), _leaves(b)):
        np.testing.assert_array_equal(x, y)


# ----------------------------------------------------------------------
# corruption model unit semantics
# ----------------------------------------------------------------------

def test_adversary_mask_deterministic_and_counted():
    cfg = _cfg(adversary_frac=0.3, attack="nan")
    m1 = np.asarray(DYN.adversary_mask(cfg))
    m2 = np.asarray(DYN.adversary_mask(cfg))
    np.testing.assert_array_equal(m1, m2)
    assert m1.sum() == round(0.3 * N_CLIENTS)
    assert not np.asarray(DYN.adversary_mask(_cfg())).any()
    # a different seed draws a different Byzantine set (whp for N=10, 3)
    m3 = np.asarray(DYN.adversary_mask(_cfg(adversary_frac=0.3,
                                            attack="nan", seed=4)))
    assert m3.sum() == m1.sum()


def _rows():
    rng = np.random.default_rng(0)
    deltas = jnp.asarray(rng.normal(size=(4, 6)).astype(np.float32))
    adv = jnp.array([True, False, True, False])
    valid = jnp.array([True, True, False, True])
    return deltas, adv, valid   # only row 0 is adv AND valid


@pytest.mark.parametrize("attack", ("nan", "scale", "signflip", "noise"))
def test_corrupt_updates_touches_only_valid_adversaries(attack):
    cfg = _cfg(adversary_frac=0.3, attack=attack, attack_scale=5.0)
    deltas, adv, valid = _rows()
    key = jax.random.PRNGKey(7)
    out = np.asarray(DYN.corrupt_updates(cfg, key, deltas, adv, valid))
    ref = np.asarray(deltas)
    # honest rows and the invalid adversarial row pass through bit-equal
    np.testing.assert_array_equal(out[1:], ref[1:])
    if attack == "nan":
        assert np.isnan(out[0]).all()
    elif attack == "scale":
        np.testing.assert_array_equal(out[0], 5.0 * ref[0])
    elif attack == "signflip":
        np.testing.assert_array_equal(out[0], -5.0 * ref[0])
    else:   # noise: perturbed, finite, and deterministic in the key
        assert np.isfinite(out[0]).all() and (out[0] != ref[0]).any()
        out2 = np.asarray(DYN.corrupt_updates(cfg, key, deltas, adv,
                                              valid))
        np.testing.assert_array_equal(out, out2)


def test_corrupt_updates_identity_when_inactive():
    deltas, adv, valid = _rows()
    key = jax.random.PRNGKey(7)
    for cfg in (_cfg(), _cfg(attack="scale"),            # frac 0
                _cfg(adversary_frac=0.3)):               # attack none
        out = DYN.corrupt_updates(cfg, key, deltas, adv, valid)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(deltas))


# ----------------------------------------------------------------------
# screened-step estimator semantics
# ----------------------------------------------------------------------

def _screen_inputs(cfg, deltas, weights, valid, adv=None, round_idx=0):
    cap = deltas.shape[0]
    adv = np.zeros(cap, bool) if adv is None else np.asarray(adv)
    ids = np.where(np.asarray(valid), np.arange(cap), -1).astype(np.int32)
    strikes = jnp.zeros((cfg.num_clients,), jnp.float32)
    key = jax.random.PRNGKey(0)
    return (jnp.asarray(deltas, jnp.float32),
            jnp.asarray(weights, jnp.float32), jnp.asarray(valid),
            jnp.asarray(adv), jnp.asarray(ids), strikes,
            AGG.init_defense_state(cfg), jnp.int32(round_idx), key)


def test_screen_none_is_plain_weighted_sum():
    cfg = _cfg(defense="none")
    screen = AGG.make_screened_step(cfg)
    rng = np.random.default_rng(1)
    deltas = rng.normal(size=(4, 8)).astype(np.float32)
    w = np.array([0.3, 0.3, 0.4, 0.0], np.float32)
    valid = np.array([True, True, True, False])
    agg, strikes, _, rep = screen(*_screen_inputs(cfg, deltas, w, valid))
    np.testing.assert_allclose(np.asarray(agg), (w * valid) @ deltas,
                               rtol=1e-6, atol=1e-7)
    assert int(rep["num_quarantined"]) == 0
    assert not np.asarray(strikes).any()


def test_screen_none_propagates_nan():
    # the attack baseline must NOT be silently rescued by quarantine
    cfg = _cfg(defense="none")
    screen = AGG.make_screened_step(cfg)
    deltas = np.ones((4, 8), np.float32)
    deltas[1] = np.nan
    w = np.full(4, 0.25, np.float32)
    agg, strikes, _, rep = screen(
        *_screen_inputs(cfg, deltas, w, np.ones(4, bool)))
    assert np.isnan(np.asarray(agg)).all()
    assert int(rep["num_quarantined"]) == 0
    assert not np.asarray(strikes).any()
    # metrics stay finite: computed over finite rows only
    assert np.isfinite(float(rep["update_norm_p50"]))


def test_quarantine_excludes_and_renormalizes():
    cfg = _cfg(defense="clip", clip_mult=1e9)   # clip never binds here
    screen = AGG.make_screened_step(cfg)
    rng = np.random.default_rng(2)
    deltas = rng.normal(size=(4, 8)).astype(np.float32)
    deltas[2] = np.inf
    w = np.array([0.2, 0.3, 0.4, 0.1], np.float32)
    valid = np.ones(4, bool)
    agg, strikes, _, rep = screen(*_screen_inputs(cfg, deltas, w, valid))
    keep = np.array([0, 1, 3])
    expect = (w[keep] / w[keep].sum()) @ deltas[keep]
    np.testing.assert_allclose(np.asarray(agg), expect, rtol=1e-5,
                               atol=1e-6)
    assert int(rep["num_quarantined"]) == 1
    assert int(rep["num_survivors"]) == 3
    # one strike scattered to the quarantined client's global id (=2)
    s = np.asarray(strikes)
    assert s[2] == 1.0 and s.sum() == 1.0


@pytest.mark.parametrize("defense", ("trimmed", "median"))
def test_trimmed_and_median_resist_outlier(defense):
    cfg = _cfg(defense=defense)
    screen = AGG.make_screened_step(cfg)
    deltas = np.ones((8, 4), np.float32)
    deltas[0] = 1e6                              # one huge-but-finite row
    w = np.full(8, 1 / 6, np.float32)
    w[6:] = 0.0
    valid = np.zeros(8, bool)
    valid[:6] = True
    agg, _, _, rep = screen(*_screen_inputs(cfg, deltas, w, valid))
    a = np.asarray(agg)
    np.testing.assert_allclose(a, 1.0, rtol=1e-5)   # outlier trimmed out
    assert int(rep["num_quarantined"]) == 0
    # defense=none would have been dragged by the outlier
    cfg0 = _cfg(defense="none")
    agg0, _, _, _ = AGG.make_screened_step(cfg0)(
        *_screen_inputs(cfg0, deltas, w, valid))
    assert np.asarray(agg0).max() > 1e4


def test_clip_defense_bounds_outlier_norm():
    cfg = _cfg(defense="clip")                   # clip_mult default
    screen = AGG.make_screened_step(cfg)
    rng = np.random.default_rng(3)
    deltas = rng.normal(size=(8, 16)).astype(np.float32)
    deltas[0] *= 1e4
    w = np.full(8, 0.125, np.float32)
    valid = np.ones(8, bool)
    agg, _, dstate, rep = screen(
        *_screen_inputs(cfg, deltas, w, valid))
    honest_max = np.abs(deltas[1:]).max()
    assert np.abs(np.asarray(agg)).max() < 10 * honest_max
    assert float(rep["clipped_frac"]) > 0
    assert float(dstate.clip_ema) > 0            # running median seeded
    assert float(rep["update_norm_p99"]) >= float(rep["update_norm_p50"])


def test_screen_zero_survivors_yields_zero_delta():
    cfg = _cfg(defense="median")
    screen = AGG.make_screened_step(cfg)
    deltas = np.full((4, 8), np.nan, np.float32)
    w = np.full(4, 0.25, np.float32)
    agg, strikes, _, rep = screen(
        *_screen_inputs(cfg, deltas, w, np.ones(4, bool)))
    np.testing.assert_array_equal(np.asarray(agg), 0.0)
    assert int(rep["num_quarantined"]) == 4
    assert int(rep["num_survivors"]) == 0
    assert np.asarray(strikes).sum() == 4.0


def test_screen_capacity_is_pow2_bound():
    cfg = _cfg()
    cap = AGG.screen_capacity(cfg)
    assert cap & (cap - 1) == 0
    assert cap >= round(cfg.select_ratio * cfg.num_clients)


# ----------------------------------------------------------------------
# bit-equality boundary: neutral knobs change NOTHING
# ----------------------------------------------------------------------

@pytest.mark.parametrize("runtime", RUNTIMES)
def test_defense_knobs_off_bit_identical(runtime, data):
    plain = _server(_cfg(runtime=runtime, rounds=2), data)
    logs_p = plain.run(rounds=2)
    # knobs present but neutral: frac 0 + defense none => defended False
    knobs = _server(_cfg(runtime=runtime, rounds=2, adversary_frac=0.0,
                         attack="scale", attack_scale=9.0,
                         defense="none"), data)
    assert not knobs.defended
    logs_k = knobs.run(rounds=2)
    _assert_trees_equal(plain.params, knobs.params)
    for lp, lk in zip(logs_p, logs_k):
        np.testing.assert_array_equal(lp.selected, lk.selected)
        assert lp.mean_bid == lk.mean_bid
    assert knobs.state.strikes is None   # feature-off pytree unchanged


# ----------------------------------------------------------------------
# cross-runtime quarantine / reputation equivalence
# ----------------------------------------------------------------------

def test_nan_attack_quarantine_equivalent_across_runtimes(data):
    outs = {}
    for rt in RUNTIMES:
        srv = _server(_cfg(runtime=rt, rounds=3, adversary_frac=0.3,
                           attack="nan", defense="median"), data)
        logs = srv.run(rounds=3)
        for lf in _leaves(srv.params):
            assert np.isfinite(lf).all()   # median survives NaN rows
        outs[rt] = (np.asarray(obs.device_get(srv.state.strikes)),
                    [np.asarray(l.selected) for l in logs],
                    srv.defense_totals["quarantined"])
    ref_s, ref_sel, ref_q = outs["sequential"]
    assert ref_q > 0                       # the attack actually landed
    for rt in RUNTIMES[1:]:
        s, sel, q = outs[rt]
        # quarantine verdicts (non-finiteness) are reassociation-immune,
        # so strikes, selections and totals match bit-for-bit
        np.testing.assert_array_equal(s, ref_s)
        assert q == ref_q
        for a, b in zip(sel, ref_sel):
            np.testing.assert_array_equal(a, b)


def test_strikes_ban_repeat_offenders(data):
    cfg = _cfg(rounds=6, adversary_frac=0.3, attack="nan",
               defense="median", strike_threshold=1.0, strike_decay=1.0)
    srv = _server(cfg, data)
    adv = np.asarray(obs.device_get(DYN.adversary_mask(cfg)), bool)
    logs = srv.run(rounds=6)
    strikes = np.asarray(obs.device_get(srv.state.strikes))
    assert (strikes[~adv] == 0).all()      # honest clients never struck
    banned_at = {}                         # client -> first banned round
    struck = set()
    for log in logs:
        for c in log.selected:
            assert int(c) not in banned_at, \
                f"client {c} selected after ban (round {log.round})"
        # strikes land AFTER this round's selection: a client struck in
        # round t is banned from round t+1 on (threshold 1, no decay)
        for c in log.selected:
            if adv[int(c)]:
                struck.add(int(c))
                banned_at.setdefault(int(c), log.round + 1)
    assert struck                          # some adversary won at least once
    assert srv.defense_totals["banned_final"] == len(struck)
    assert (strikes[list(struck)] >= cfg.strike_threshold).all()


# ----------------------------------------------------------------------
# eval_skipped flag + divergence accounting (satellite S2)
# ----------------------------------------------------------------------

def test_eval_skipped_flag_tracks_cadence(data, tmp_path):
    path = str(tmp_path / "ev.jsonl")
    obs.OBS.configure(jsonl=path, memory=True)
    srv = _server(_cfg(rounds=4, eval_every=2), data)
    logs = srv.run(rounds=4)
    obs.OBS.flush()
    for log in logs:
        due = log.round % 2 == 0 or log.round == 3
        assert log.eval_skipped == (not due)
        assert np.isnan(log.test_acc) == log.eval_skipped
    assert validate_events(load_jsonl(path), rounds=4, eval_every=2) == []


def test_undefended_nan_attack_flags_divergence(data, tmp_path):
    path = str(tmp_path / "ev.jsonl")
    obs.OBS.configure(jsonl=path, memory=True)
    srv = _server(_cfg(rounds=3, eval_every=1, adversary_frac=0.3,
                       attack="nan", defense="none"), data)
    logs = srv.run(rounds=3)
    obs.OBS.flush()
    diverged = [l for l in logs
                if not l.eval_skipped and not np.isfinite(l.test_loss)]
    assert diverged                        # NaN reached the globals
    events = load_jsonl(path)
    assert any(e.get("kind") == "defense"
               and e.get("name") == "round/diverged" for e in events)
    # NaN acc with eval_skipped=false is legal under the new schema
    assert validate_events(events, rounds=3, eval_every=1) == []


# ----------------------------------------------------------------------
# compile-once policy: defended warm loop never retraces
# ----------------------------------------------------------------------

def test_device_defended_warm_loop_zero_retrace(data):
    cfg = _cfg(runtime="device", rounds=8, adversary_frac=0.3,
               attack="scale", defense="trimmed")
    srv = _server(cfg, data)
    base = obs.jax_stats.snapshot()        # process-wide counters: other
    srv.run(rounds=3)                      # tests may have compiled too
    snap = obs.jax_stats.snapshot()
    assert obs.jax_stats.delta(base).get("traces/screened_agg") == 1
    for t in range(3, 8):                  # shifting cohorts, warm
        srv._dispatch_round(t, eval_now=False)
    srv._flush_pending()
    d = obs.jax_stats.delta(snap)
    retraces = {k: v for k, v in d.items() if k.startswith("traces")}
    assert not retraces, retraces
