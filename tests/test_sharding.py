"""Sharding-rule tests: spec trees mirror parameter trees, divisibility
sanitization, and cache-spec selection logic."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need the optional hypothesis extra")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES_BY_NAME
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch import specs as SP
from repro.sharding import rules as R

AXIS = {"data": 16, "model": 16}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_cover_every_leaf(arch):
    cfg = get_config(arch)
    pshape = SP.params_shape(cfg)
    specs = R.param_specs(cfg, pshape)
    leaves_p = jax.tree_util.tree_leaves(pshape)
    leaves_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_p) == len(leaves_s)
    for lp, ls in zip(leaves_p, leaves_s):
        assert isinstance(ls, P)
        assert len(ls) <= len(lp.shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_sanitized_specs_divide_evenly(arch):
    cfg = get_config(arch)
    pshape = SP.params_shape(cfg)
    specs = R.sanitize_specs(R.param_specs(cfg, pshape), pshape, AXIS)

    def check(spec, leaf):
        for dim, entry in zip(leaf.shape,
                              tuple(spec) + (None,) * len(leaf.shape)):
            n = R._n_shards(entry, AXIS)
            assert dim % n == 0, (arch, leaf.shape, spec)
        return spec

    jax.tree.map(check, specs, pshape, is_leaf=lambda x: isinstance(x, P))


def test_big_matrices_are_sharded():
    """FSDP sanity: the large 2D weights of a dense arch must be sharded on
    both mesh axes (no accidental replication of the bulk parameters)."""
    cfg = get_config("qwen1.5-32b")
    pshape = SP.params_shape(cfg)
    specs = R.sanitize_specs(R.param_specs(cfg, pshape), pshape, AXIS)
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(pshape)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = leaf
    sflat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        sflat[key] = leaf
    n_big_sharded = 0
    for k, leaf in flat.items():
        n = 1
        for d in leaf.shape:
            n *= d
        if n >= 16 * 2**20:     # >= 16M elements
            spec = sflat[k]
            assert any(e is not None for e in spec), (k, spec)
            n_big_sharded += 1
    assert n_big_sharded >= 4


@given(kv=st.sampled_from([2, 4, 8, 16, 32, 40]),
       batch=st.sampled_from([1, 32, 128, 256]))
@settings(max_examples=20, deadline=None)
def test_cache_spec_divisibility(kv, batch):
    """KV-head dim takes 'model' only when divisible; otherwise the 32k
    sequence dim does."""
    shape = (24, batch, 32768, kv, 64)
    spec = R._cache_leaf_spec("self/0/k", shape,
                              batch_sharded=batch % 16 == 0 and batch >= 16,
                              axis_sizes=AXIS)
    for dim, entry in zip(shape, tuple(spec) + (None,) * 5):
        assert dim % R._n_shards(entry, AXIS) == 0


def test_decode_state_specs_all_shapes():
    for arch in ("qwen1.5-32b", "jamba-v0.1-52b", "xlstm-1.3b",
                 "whisper-tiny"):
        cfg = get_config(arch)
        for shp in ("decode_32k", "long_500k"):
            shape = SHAPES_BY_NAME[shp]
            if shp == "long_500k" and not cfg.supports_long_context():
                continue
            sshape = SP.decode_state_shape(cfg, shape)
            specs = R.sanitize_specs(
                R.decode_state_specs(cfg, sshape, shape.global_batch, AXIS),
                sshape, AXIS)
            jax.tree.map(
                lambda sp, lf: [
                    d % R._n_shards(e, AXIS) == 0 or pytest.fail(str((sp, lf)))
                    for d, e in zip(lf.shape, tuple(sp) + (None,) * 8)],
                specs, sshape, is_leaf=lambda x: isinstance(x, P))


def test_long_context_rule():
    assert get_config("xlstm-1.3b").supports_long_context()
    assert get_config("jamba-v0.1-52b").supports_long_context()
    assert get_config("starcoder2-3b").supports_long_context()  # SW 4096
    assert not get_config("qwen1.5-32b").supports_long_context()
    assert not get_config("qwen3-moe-235b-a22b").supports_long_context()
    assert not get_config("whisper-tiny").supports_long_context()
