"""Observability layer (repro.obs): registry/sink/span mechanics, the
event schema, and — most importantly — the neutrality guarantees:
instrumentation must not add retraces, blocking fetches, or implicit
host transfers to the round pipeline, and the logs it observes must be
bit-identical to an uninstrumented run's.  Also regression-tests the
verbose-print eval bug (progress printing used to force off-cadence
evals, so logs and params depended on the ``verbose`` flag)."""
import io
import json
import math
from contextlib import redirect_stdout

import jax
import numpy as np
import pytest

from repro import obs
from repro.configs.base import FLConfig
from repro.core.adapters import cnn_adapter
from repro.core.server import FederatedServer
from repro.data.partition import partition_clients
from repro.data.synthetic import make_image_dataset
from repro.obs.schema import load_jsonl, validate_events
from repro.obs.sinks import sanitize_event

N_CLIENTS = 10
POOL = 700


def _cfg(**kw):
    base = dict(num_clients=N_CLIENTS, num_clusters=3, select_ratio=0.4,
                rounds=2, local_epochs=2, sample_window=10,
                cluster_resamples=2, init_energy_mode="normal", seed=3)
    base.update(kw)
    return FLConfig(**base)


@pytest.fixture(scope="module")
def data():
    train, test = make_image_dataset("mnist", n_train=POOL, n_test=120,
                                     seed=3)
    return train, test


@pytest.fixture(scope="module")
def clients(data):
    train, _ = data
    return partition_clients(train.y, _cfg(), seed=3)


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with obs disabled and counters zeroed
    (OBS is a process singleton)."""
    obs.OBS.reset()
    yield
    obs.OBS.reset()


def _server(data, clients, **cfg_kw):
    train, test = data
    cfg = _cfg(**cfg_kw)
    return FederatedServer(cfg, cnn_adapter("mnist"), train.x, train.y,
                           clients, {"x": test.x[:64], "y": test.y[:64]})


def _canon(v):
    # NaN != NaN would make off-cadence rounds incomparable
    return "nan" if isinstance(v, float) and math.isnan(v) else v


def _log_tuples(logs):
    return [tuple(map(_canon, (l.round, l.test_acc, l.test_loss,
                               l.energy_std, l.mean_bid, l.server_reward,
                               l.client_reward_sum, l.vds_gap)))
            + (tuple(l.selected.tolist()),) for l in logs]


# ----------------------------------------------------------------------
# registry / span / sink mechanics
# ----------------------------------------------------------------------

def test_disabled_is_noop():
    assert not obs.OBS.enabled
    # the hot-path entry points must not buffer anything while disabled
    s = obs.span("x")
    assert s is obs.span("y"), "disabled span must be the shared null cm"
    with s:
        pass
    obs.OBS.event("round", round=0)
    obs.OBS.record_round(1, test_acc=1.0)
    assert obs.OBS._buffer == []


def test_span_nesting_and_schema():
    mem = obs.configure(memory=True)
    with obs.span("run/cluster"):
        with obs.span("cluster/kmeans", k=3):
            pass
    with obs.span("round/dispatch", round=0):
        with obs.span("round/select", round=0):
            pass
    obs.OBS.record_round(0, test_acc=0.5, test_loss=1.0, energy_std=0.1,
                         mean_bid=0.2, vds_gap=0.3)
    with obs.span("round/drain", rounds=1):
        pass
    obs.flush()
    errs = validate_events(mem.events, rounds=1, eval_every=1)
    assert errs == [], errs
    spans = {e["name"]: e for e in mem.events if e["kind"] == "span"}
    assert spans["cluster/kmeans"]["parent"] == spans["run/cluster"]["id"]
    assert spans["cluster/kmeans"]["depth"] == 1
    assert spans["round/select"]["parent"] == spans["round/dispatch"]["id"]
    assert spans["run/cluster"]["parent"] is None
    # meta keys clashing with schema fields are renamed, not dropped
    with obs.span("x", kind="boom", note="ok"):
        pass
    obs.flush()
    e = [v for v in mem.events if v.get("name") == "x"][0]
    assert e["kind"] == "span" and e["meta_kind"] == "boom" \
        and e["note"] == "ok"


def test_sinks_sanitize_nan_and_jsonl_roundtrip(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    obs.configure(jsonl=path)
    obs.OBS.record_round(0, test_acc=float("nan"), test_loss=float("inf"),
                         energy_std=0.5, mean_bid=0.1, vds_gap=0.2)
    obs.OBS.counter("pack/buckets", 3)
    obs.flush()
    events = load_jsonl(path)       # strict JSON: NaN would raise here
    row = [e for e in events if e["kind"] == "round"][0]
    assert row["test_acc"] is None and row["test_loss"] is None
    assert row["energy_std"] == 0.5
    ctr = [e for e in events if e["kind"] == "counter"][0]
    assert ctr["name"] == "pack/buckets" and ctr["value"] == 3
    assert sanitize_event({"a": math.nan, "b": 1.5}) == {"a": None,
                                                         "b": 1.5}


def test_jax_stats_counters_and_transfer_accounting():
    st0 = obs.jax_stats.snapshot()
    arr = np.ones((8, 4), np.float32)
    dev = obs.device_put(arr)
    back = obs.device_get(dev)
    d = obs.jax_stats.delta(st0)
    assert d["h2d_bytes"] == arr.nbytes and d["h2d_calls"] == 1
    assert d["d2h_bytes"] == back.nbytes and d["d2h_calls"] == 1

    @jax.jit
    def f(x):
        obs.jax_stats.note_trace("t_test")
        return x * 2

    st1 = obs.jax_stats.snapshot()
    f(dev)
    f(dev)    # cache hit: no second trace
    d = obs.jax_stats.delta(st1)
    assert d.get("traces/t_test") == 1


def test_sync_audit_flags_implicit_transfers():
    f = jax.jit(lambda x: x + 1)
    host = np.ones((4,), np.float32)
    f(host)   # compile outside the guard
    with pytest.raises(Exception, match="[Dd]isallow"):
        with obs.sync_audit():
            jax.block_until_ready(f(host))   # implicit h2d
    dev = obs.device_put(host)
    with obs.sync_audit():                   # explicit transfers are legal
        out = f(dev)
        obs.device_get(out)


# ----------------------------------------------------------------------
# satellite 1: verbose printing must not change eval cadence
# ----------------------------------------------------------------------

def test_verbose_does_not_force_evals(data, clients):
    rounds, eval_every = 5, 3
    srv_q = _server(data, clients, eval_every=eval_every)
    logs_q = srv_q.run(rounds=rounds, verbose=False)
    srv_v = _server(data, clients, eval_every=eval_every)
    with redirect_stdout(io.StringIO()) as cap:
        logs_v = srv_v.run(rounds=rounds, verbose=True)
    # logs AND params bit-identical with verbose on/off (the old code
    # forced an eval at every print boundary, so they weren't)
    assert _log_tuples(logs_q) == _log_tuples(logs_v)
    for a, b in zip(jax.tree.leaves(jax.device_get(srv_q.params)),
                    jax.tree.leaves(jax.device_get(srv_v.params))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # eval cadence: due exactly on multiples of eval_every + final round
    for l in logs_v:
        due = l.round % eval_every == 0 or l.round == rounds - 1
        assert math.isnan(l.test_acc) != due
    # the round-0 progress line shows round 0's drained eval
    assert "round   0 acc=0." in cap.getvalue()


# ----------------------------------------------------------------------
# tentpole: instrumentation neutrality on the device round pipeline
# ----------------------------------------------------------------------

def test_observability_is_neutral_on_device_runtime(data, clients,
                                                    tmp_path):
    rounds = 4
    # uninstrumented twin first (obs disabled via the autouse fixture)
    srv0 = _server(data, clients, runtime="device", eval_every=2)
    logs0 = srv0.run(rounds=rounds)
    params0 = jax.device_get(srv0.params)

    path = str(tmp_path / "ev.jsonl")
    mem = obs.configure(jsonl=path, memory=True)
    srv1 = _server(data, clients, runtime="device", eval_every=2)
    # warm-up: clustering + class compiles + rounds 0-1 (same eval
    # cadence as run(rounds=4) — round 1 is NOT final here)
    srv1.cluster()
    srv1.runtime.warmup(srv1.params)
    for t in range(2):
        srv1._dispatch_round(t, srv1._eval_due(t, final=False))
    srv1._flush_pending()
    st = obs.jax_stats.snapshot()
    with obs.sync_audit():                  # no implicit host transfers
        for t in range(2, rounds):
            srv1._dispatch_round(t, srv1._eval_due(t, final=t == rounds - 1))
    srv1._flush_pending()
    d = obs.jax_stats.delta(st)
    assert not any(k.startswith("traces") for k in d), \
        f"instrumented warm rounds retraced: {d}"

    # selection/energy logs bit-identical to the uninstrumented twin
    assert _log_tuples(logs0) == _log_tuples(srv1.logs)
    params1 = jax.device_get(srv1.params)
    for a, b in zip(jax.tree.leaves(params0), jax.tree.leaves(params1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    obs.flush()
    errs = validate_events(mem.events, rounds=rounds, eval_every=2)
    assert errs == [], errs
    # the JSONL mirror carries the same stream
    assert validate_events(load_jsonl(path), rounds=rounds,
                           eval_every=2) == []
    # dispatch and drain are recorded separately
    names = [e["name"] for e in mem.events if e["kind"] == "span"]
    assert names.count("round/dispatch") == rounds
    assert "round/drain" in names


def test_schema_validator_catches_violations():
    base = {"kind": "span", "ts": 1.0, "name": "a", "id": 1,
            "parent": None, "depth": 0, "t0": 0.0, "dur_s": 1.0}
    # child escaping its parent's window
    bad_child = {"kind": "span", "ts": 3.0, "name": "b", "id": 2,
                 "parent": 1, "depth": 1, "t0": 0.5, "dur_s": 5.0}
    errs = validate_events([base, bad_child])
    assert any("escapes" in e for e in errs)
    # wrong depth
    bad_depth = dict(bad_child, t0=0.1, dur_s=0.1, depth=4)
    assert any("depth" in e for e in validate_events([base, bad_depth]))
    # duplicate round rows + off-cadence eval number
    r = {"kind": "round", "ts": 1.0, "round": 1, "test_acc": 0.5,
         "test_loss": 1.0, "energy_std": 0.1, "mean_bid": 0.2,
         "vds_gap": 0.3}
    r0 = dict(r, round=0, test_acc=None, test_loss=None)
    disp = [dict(base, id=10 + t, name="round/dispatch", round=t)
            for t in range(2)]
    drain = dict(base, id=20, name="round/drain")
    errs = validate_events([r0, r, *disp, drain], rounds=2, eval_every=2)
    assert any("eval due but" in e for e in errs)       # round 0 null
    errs = validate_events([r, dict(r), *disp, drain], rounds=2,
                           eval_every=2)
    assert any("duplicate series row" in e for e in errs)
