"""Selection-scheme registry (repro.core.schemes): refactor neutrality
(the 'paper' scheme through the scheme interface is bit-identical to the
pre-registry control plane), per-scheme semantics (fedcs never picks a
deadline-infeasible winner, longterm budget monotonicity, random matches
its reference sampler under the same key chain), zero warm retraces for
every scheme on the scan fast path, and the obs schema's scheme-tagged
scalar rules."""
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs.base import FLConfig
from repro.core import rounds as RND
from repro.core import schemes as SCH
from repro.core import selection as SEL
from repro.obs import schema as SCHEMA

ALL_SCHEMES = ("paper", "random", "fedcs", "longterm_auction")


@pytest.fixture(autouse=True)
def _obs_reset():
    obs.OBS.reset()
    yield
    obs.OBS.reset()


def _cfg(**kw):
    base = dict(num_clients=60, num_clusters=5, select_ratio=0.2, seed=0)
    base.update(kw)
    return FLConfig(**base)


def _fleet(cfg, seed=0):
    return RND.synthetic_fleet(cfg, jax.random.PRNGKey(seed))


# ----------------------------------------------------------------------
# registry basics
# ----------------------------------------------------------------------

def test_registry_lists_the_zoo():
    assert set(ALL_SCHEMES) <= set(SCH.scheme_names())


def test_unknown_scheme_errors_with_names():
    with pytest.raises(KeyError, match="registered schemes"):
        SCH.get_scheme("definitely_not_a_scheme")


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        SCH.register(SCH.get_scheme("paper"))


def test_scheme_state_init_shapes():
    cfg = _cfg(scheme_select="longterm_auction")
    ss = SCH.init_scheme_state(cfg)
    assert isinstance(ss, SCH.LongTermState)
    assert ss.paid.shape == (cfg.num_clients,)
    assert float(ss.spent) == 0.0 and float(ss.queue) == 0.0
    for name in ("paper", "random", "fedcs"):
        assert SCH.init_scheme_state(_cfg(scheme_select=name)) is None


# ----------------------------------------------------------------------
# refactor neutrality: 'paper' == the pre-registry control plane
# ----------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg",))
def _pre_registry_round(state, key, cfg):
    """The control-plane round exactly as the pre-registry _round_body
    computed it (select_round -> rewards -> energy/history update, with
    the strikes trust gate composed upstream) — the neutrality oracle."""
    avail = None
    if state.strikes is not None:
        avail = state.strikes < cfg.strike_threshold
    win, info = SEL.select_round(state, cfg, key, avail=avail)
    client_r, server_r = RND.round_rewards(win, info["bids"],
                                           state.local_sizes, cfg)
    return SEL.update_after_round(state, win, cfg), win, client_r


@pytest.mark.parametrize("scheme", ["gradient_cluster_auction",
                                    "gradient_cluster_random", "random"])
def test_paper_scheme_bit_identical_to_pre_registry(scheme):
    cfg = _cfg(scheme=scheme, scheme_select="paper")
    state = _fleet(cfg)
    key = jax.random.PRNGKey(7)
    for _ in range(4):
        key, k = jax.random.split(key)
        ref_state, ref_win, _ = _pre_registry_round(state, k, cfg)
        new_state, win, metrics = RND._round_step_jit(
            state, k, None, None, cfg, "segmented")
        np.testing.assert_array_equal(np.asarray(win), np.asarray(ref_win))
        np.testing.assert_array_equal(np.asarray(new_state.residual),
                                      np.asarray(ref_state.residual))
        np.testing.assert_array_equal(np.asarray(new_state.history),
                                      np.asarray(ref_state.history))
        assert new_state.scheme_state is None
        state = new_state


def test_paper_scheme_bit_identical_with_strikes():
    # the defended state (strikes ledger) rides the same neutrality rule
    cfg = _cfg(scheme_select="paper", defense="median")
    state = _fleet(cfg)
    strikes = jnp.zeros((cfg.num_clients,), jnp.float32).at[3].set(5.0)
    state = dataclasses.replace(state, strikes=strikes)
    key = jax.random.PRNGKey(11)
    ref_state, ref_win, _ = _pre_registry_round(state, key, cfg)
    new_state, win, metrics = RND._round_step_jit(
        state, key, None, None, cfg, "segmented")
    np.testing.assert_array_equal(np.asarray(win), np.asarray(ref_win))
    np.testing.assert_array_equal(np.asarray(new_state.strikes),
                                  np.asarray(ref_state.strikes))
    assert not bool(np.asarray(win)[3])      # banned client never wins
    assert int(metrics["num_banned"]) == 1


def test_paper_scan_matches_reference_oracle():
    # the scan fast path and the eager per-round reference stay the
    # bit-identity pair under the scheme dispatch
    cfg = _cfg(scheme_select="paper")
    state = _fleet(cfg)
    key = jax.random.PRNGKey(3)
    _, m_scan, w_scan = RND.simulate_rounds(state, cfg, key, 5,
                                            record_wins=True)
    _, m_ref, w_ref = RND.simulate_rounds_reference(state, cfg, key, 5,
                                                    record_wins=True)
    np.testing.assert_array_equal(np.asarray(w_scan), w_ref)
    for k in m_ref:
        np.testing.assert_array_equal(np.asarray(m_scan[k]), m_ref[k])


@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_scan_matches_reference_for_every_scheme(scheme):
    cfg = _cfg(scheme_select=scheme)
    state = _fleet(cfg)
    key = jax.random.PRNGKey(5)
    _, m_scan, w_scan = RND.simulate_rounds(state, cfg, key, 4,
                                            record_wins=True)
    _, m_ref, w_ref = RND.simulate_rounds_reference(state, cfg, key, 4,
                                                    record_wins=True)
    np.testing.assert_array_equal(np.asarray(w_scan), w_ref)
    for k in m_ref:
        np.testing.assert_array_equal(np.asarray(m_scan[k]), m_ref[k],
                                      err_msg=k)


# ----------------------------------------------------------------------
# zero warm retraces: every scheme compiles into ONE scan program
# ----------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_scan_zero_warm_retraces(scheme):
    cfg = _cfg(scheme_select=scheme)
    state = _fleet(cfg)
    key = jax.random.PRNGKey(9)
    out = RND.simulate_rounds(state, cfg, key, 3)
    jax.block_until_ready(out[1])
    snap = obs.jax_stats.snapshot()
    out = RND.simulate_rounds(state, cfg, key, 3)
    jax.block_until_ready(out[1])
    d = obs.jax_stats.delta(snap)
    traces = {k: v for k, v in d.items() if k.startswith("traces")}
    assert not traces, f"warm scan retraced under {scheme!r}: {traces}"


# ----------------------------------------------------------------------
# per-scheme semantics
# ----------------------------------------------------------------------

def test_random_matches_reference_sampler_under_same_key_chain():
    cfg = _cfg(scheme_select="random")
    state = _fleet(cfg)
    avail = jnp.arange(cfg.num_clients) % 3 != 0     # some offline
    key = jax.random.PRNGKey(13)
    win, info = SCH.random_select(state, cfg, key, avail=avail)
    # the oracle consumes keys[1] of the same 4-way split and applies
    # the same post-pick hard availability mask
    keys = jax.random.split(key, 4)
    ref = SEL._random_per_cluster_loop(keys[1], state, cfg, avail) & avail
    np.testing.assert_array_equal(np.asarray(win), np.asarray(ref))
    assert not np.asarray(win & ~avail).any()
    assert float(info["bids"].sum()) == 0.0


def test_fedcs_never_picks_infeasible_winner():
    cfg = _cfg(scheme_select="fedcs", fedcs_deadline=1.0,
               init_energy_mode="normal")
    state = _fleet(cfg, seed=2)
    for s in range(5):
        key = jax.random.PRNGKey(100 + s)
        win, info = SCH.fedcs_select(state, cfg, key)
        lat = np.asarray(info["pred_latency"])
        w = np.asarray(win)
        assert (lat[w] <= SCH.fedcs_deadline(cfg)).all()
        # the prediction is deterministic given (key, state)
        lat2 = np.asarray(SCH.fedcs_predicted_latency(state, cfg, key))
        np.testing.assert_array_equal(lat, lat2)


def test_fedcs_deadline_prefers_enforced_deadline():
    assert SCH.fedcs_deadline(_cfg(deadline=1.2, fedcs_deadline=9.0)) == 1.2
    assert SCH.fedcs_deadline(_cfg(deadline=0.0, fedcs_deadline=9.0)) == 9.0


def test_fedcs_gating_is_a_strict_subset_of_paper():
    # feasibility only removes winners relative to an infinite deadline
    cfg_loose = _cfg(scheme_select="fedcs", fedcs_deadline=1e9)
    cfg_tight = cfg_loose.replace(fedcs_deadline=0.8)
    state = _fleet(cfg_loose, seed=4)
    key = jax.random.PRNGKey(21)
    win_loose, _ = SCH.fedcs_select(state, cfg_loose, key)
    win_paper, _ = SEL.select_round(state, cfg_loose, key)
    np.testing.assert_array_equal(np.asarray(win_loose),
                                  np.asarray(win_paper))
    win_tight, info = SCH.fedcs_select(state, cfg_tight, key)
    assert int(win_tight.sum()) <= int(win_loose.sum())


def test_longterm_budget_monotone_and_queue_nonnegative():
    cfg = _cfg(scheme_select="longterm_auction", total_reward=20.0,
               target_rounds=10)
    state = _fleet(cfg)
    _, m, _ = RND.simulate_rounds(state, cfg, jax.random.PRNGKey(1), 30)
    m = jax.device_get(m)
    remaining = np.asarray(m["budget_remaining"])
    assert (np.diff(remaining) <= 1e-5).all()        # spent is monotone
    assert (np.asarray(m["budget_queue"]) >= 0.0).all()
    assert (np.asarray(m["budget_spent"]) >= 0.0).all()
    # per-round spend is exactly the reward model's client payout
    np.testing.assert_allclose(np.asarray(m["budget_spent"]),
                               np.asarray(m["client_reward_sum"]),
                               rtol=1e-6)


def test_longterm_exhausted_budget_selects_no_one():
    cfg = _cfg(scheme_select="longterm_auction", total_reward=1.0,
               target_rounds=100)
    state = _fleet(cfg)
    ss = SCH.LongTermState(spent=jnp.float32(1.5), queue=jnp.float32(0.0),
                           paid=jnp.zeros((cfg.num_clients,), jnp.float32))
    state = dataclasses.replace(state, scheme_state=ss)
    win, _ = SCH.longterm_select(state, cfg, jax.random.PRNGKey(0))
    assert int(win.sum()) == 0


def test_longterm_backlog_caps_admissible_bids():
    cfg = _cfg(scheme_select="longterm_auction")
    state = _fleet(cfg, seed=6)
    key = jax.random.PRNGKey(2)
    # huge backlog -> cap near 0 -> nobody's Nash bid is admissible
    per_round = cfg.total_reward / cfg.target_rounds
    ss = SCH.LongTermState(spent=jnp.float32(0.0),
                           queue=jnp.float32(1e6 * per_round),
                           paid=jnp.zeros((cfg.num_clients,), jnp.float32))
    st = dataclasses.replace(state, scheme_state=ss)
    win, _ = SCH.longterm_select(st, cfg, key)
    assert int(win.sum()) == 0
    # zero backlog -> cap 1.0 -> identical to the paper's auction (bids
    # are clipped into [0, 1], so the cap is a no-op)
    st0 = dataclasses.replace(state,
                              scheme_state=SCH._longterm_init(cfg))
    win0, _ = SCH.longterm_select(st0, cfg, key)
    ref, _ = SEL.select_round(state, cfg, key)
    np.testing.assert_array_equal(np.asarray(win0), np.asarray(ref))


def test_longterm_without_state_raises():
    cfg = _cfg(scheme_select="longterm_auction")
    state = _fleet(_cfg(scheme_select="paper"))     # scheme_state=None
    with pytest.raises(ValueError, match="needs scheme_state"):
        SCH.longterm_select(state, cfg, jax.random.PRNGKey(0))


def test_host_replacement_mask_fedcs_only():
    sizes = np.array([100, 5000, 300], np.int64)
    assert SCH.host_replacement_mask(_cfg(), sizes) is None
    m = SCH.host_replacement_mask(
        _cfg(scheme_select="fedcs", fedcs_deadline=1.0), sizes)
    assert m is not None and m.dtype == bool
    assert not m[1]      # the outsized client can't plausibly make it


# ----------------------------------------------------------------------
# obs schema: scheme-tagged scalar series
# ----------------------------------------------------------------------

def test_schema_stateful_schemes_mirror_the_registry():
    assert tuple(SCHEMA.STATEFUL_SCHEMES) == SCH.stateful_scheme_names()


def _round_rows(rows):
    evs = [{"kind": "meta", "ts": 0.0}]
    for r, extra in enumerate(rows):
        e = {"kind": "round", "ts": float(r + 1), "round": r,
             "test_acc": 0.5, "test_loss": 1.0, "energy_std": 0.1,
             "mean_bid": 0.2, "vds_gap": 0.0}
        e.update(extra)
        evs.append(e)
    return evs


def test_schema_accepts_scheme_tagged_stream():
    evs = _round_rows([{"fairness_hist_std": 0.3, "budget_spent": 1.0,
                        "budget_remaining": 9.0, "budget_queue": 0.0}] * 3)
    assert SCHEMA.validate_events(evs,
                                  scheme_select="longterm_auction") == []
    assert SCHEMA.validate_events(evs, scheme_select="paper") == []


def test_schema_rejects_stateful_scheme_without_budget_scalars():
    evs = _round_rows([{"fairness_hist_std": 0.3}] * 2)
    errs = SCHEMA.validate_events(evs, scheme_select="longterm_auction")
    assert errs and any("budget_spent" in e for e in errs)
    # …but the same stream is fine for a stateless scheme
    assert SCHEMA.validate_events(evs, scheme_select="fedcs") == []


def test_schema_rejects_missing_fairness_scalar():
    evs = _round_rows([{}] * 2)
    errs = SCHEMA.validate_events(evs, scheme_select="paper")
    assert errs and any("fairness_hist_std" in e for e in errs)
    # without a scheme tag the stream validates as before
    assert SCHEMA.validate_events(evs) == []


# ----------------------------------------------------------------------
# server integration: neutrality across runtimes + scheme metric drain
# ----------------------------------------------------------------------

RUNTIMES = ("sequential", "vectorized", "sharded", "device")


@pytest.fixture(scope="module")
def _mnist():
    from repro.data.synthetic import make_image_dataset
    return make_image_dataset("mnist", n_train=700, n_test=120, seed=3)


def _run_server(data, rounds=3, **kw):
    from repro.core.adapters import cnn_adapter
    from repro.core.server import FederatedServer
    from repro.data.partition import partition_clients
    train, test = data
    base = dict(num_clients=10, num_clusters=3, select_ratio=0.4,
                rounds=rounds, sample_window=10, cluster_resamples=2,
                init_energy_mode="normal", seed=3)
    base.update(kw)
    cfg = FLConfig(**base)
    clients = partition_clients(train.y, cfg, seed=3)
    srv = FederatedServer(cfg, cnn_adapter("mnist"), train.x, train.y,
                          clients, {"x": test.x[:64], "y": test.y[:64]})
    logs = srv.run(rounds=rounds)
    return srv, logs


def test_paper_scheme_neutral_across_all_runtimes(_mnist):
    # the control plane is runtime-independent: the paper scheme through
    # the registry produces identical selections, residual energy and
    # participation history on every cohort execution backend
    results = {}
    for rt in RUNTIMES:
        srv, logs = _run_server(_mnist, runtime=rt, scheme_select="paper")
        results[rt] = (
            [l.selected for l in logs],
            np.asarray(obs.device_get(srv.state.residual)),
            np.asarray(obs.device_get(srv.state.history)))
    sel0, res0, hist0 = results["sequential"]
    for rt in RUNTIMES[1:]:
        sel, res, hist = results[rt]
        for a, b in zip(sel0, sel):
            np.testing.assert_array_equal(a, b, err_msg=rt)
        np.testing.assert_array_equal(res0, res, err_msg=rt)
        np.testing.assert_array_equal(hist0, hist, err_msg=rt)


@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_server_drains_scheme_scalars(_mnist, scheme):
    mem = obs.OBS.configure(memory=True)
    srv, logs = _run_server(_mnist, scheme_select=scheme)
    assert len(logs) == 3
    rows = [e for e in mem.events if e.get("kind") == "round"]
    assert len(rows) == 3
    errs = SCHEMA.validate_events(mem.events, rounds=3, eval_every=1,
                                  scheme_select=scheme)
    assert errs == [], errs
    if scheme == "longterm_auction":
        assert isinstance(srv.state.scheme_state, SCH.LongTermState)
        spent = [r["budget_spent"] for r in rows]
        assert all(s >= 0.0 for s in spent)
