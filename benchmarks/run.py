"""Benchmark harness — one benchmark per paper table/figure plus kernel and
selection micro-benchmarks. Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # standard pass
  PYTHONPATH=src python -m benchmarks.run --quick    # CI-sized
  PYTHONPATH=src python -m benchmarks.run --only fig6
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULTS = os.path.join(os.path.dirname(__file__), "results")
REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _t(fn, n=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.time() - t0) / n * 1e6  # us


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)


def _save(name, obj):
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, f"{name}.json"), "w") as f:
        json.dump(obj, f, indent=1)


def _git_commit() -> str:
    import subprocess
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _summary(name, **headline):
    """Write the top-level ``BENCH_<name>.json`` perf-trajectory summary:
    the benchmark's headline numbers stamped with wall time + commit, so
    ``git log -p BENCH_round_pipeline.json`` IS the perf history."""
    rec = {"bench": name, "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
           "commit": _git_commit(), **headline}
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
        f.write("\n")


# ----------------------------------------------------------------------
# micro: kernels
# ----------------------------------------------------------------------

def bench_kernels(quick: bool):
    from repro.kernels import ref
    from repro.kernels.kmeans import kmeans_assign
    key = jax.random.PRNGKey(0)
    n, f, k = (512, 128, 10) if quick else (4096, 256, 10)
    x = jax.random.normal(key, (n, f))
    c = jax.random.normal(jax.random.fold_in(key, 1), (k, f))
    us_ref = _t(lambda: ref.kmeans_assign_ref(x, c))
    lab_p = kmeans_assign(x, c)[0]      # interpret probed per backend
    us_pal = _t(lambda: kmeans_assign(x, c)[0])
    match = bool((lab_p == ref.kmeans_assign_ref(x, c)).all())
    _row("kmeans_assign_ref", us_ref, f"N={n} F={f} K={k}")
    _row("kmeans_assign_pallas", us_pal, f"match={match}")

    from repro.models.layers import chunked_attention, naive_attention
    B, S, H, hd = (1, 512, 4, 64) if quick else (2, 2048, 8, 64)
    q, kk, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, hd))
                for i in range(3))
    fa = jax.jit(lambda q, k, v: chunked_attention(q, k, v, causal=True))
    na = jax.jit(lambda q, k, v: naive_attention(q, k, v, causal=True))
    us_f = _t(lambda: fa(q, kk, v))
    us_n = _t(lambda: na(q, kk, v))
    err = float(jnp.max(jnp.abs(fa(q, kk, v) - na(q, kk, v))))
    _row("flash_attention_jnp", us_f, f"S={S} err_vs_naive={err:.1e}")
    _row("naive_attention", us_n, f"S={S}")


# ----------------------------------------------------------------------
# micro: stage-1 clustering engine
# ----------------------------------------------------------------------

def bench_clustering(quick: bool):
    """Fused jitted k-means engine (batched restarts + incremental ++ +
    fused assign/update) vs the seed implementation (Python restart loop,
    (N,K,F)-broadcast seeding, assign_ref) across an N sweep. The seed
    baseline is skipped above 20k clients — its seeding alone materializes
    an N*K*F float buffer per centroid pick (1 GB at N=100k)."""
    from repro.core import clustering as CL
    ns = [512, 2048] if quick else [2048, 10_000, 50_000, 100_000]
    f, k = 256, 10
    ref_cap = 2048 if quick else 20_000
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(k, f)) * 8.0
    key = jax.random.PRNGKey(0)
    out = {}
    for n in ns:
        sizes = [n // k + (1 if i < n % k else 0) for i in range(k)]
        x = jnp.asarray(np.concatenate(
            [c + rng.normal(size=(s, f)) for c, s in zip(centers, sizes)]),
            jnp.float32)
        assert x.shape[0] == n
        lab_new, _ = jax.block_until_ready(CL.kmeans(x, k, key))  # warmup
        us_new = _t(lambda: CL.kmeans(x, k, key), n=3, warmup=0)
        row = {"fused_us": us_new, "N": n, "F": f, "K": k}
        derived = ""
        if n <= ref_cap:
            # one eager reference run doubles as warmup and label source
            lab_ref, _ = jax.block_until_ready(
                CL.kmeans_reference(x, k, key))
            us_ref = _t(lambda: CL.kmeans_reference(x, k, key),
                        n=1, warmup=0)
            agree = float((np.asarray(lab_new) == np.asarray(lab_ref))
                          .mean())
            row.update(reference_us=us_ref, speedup=us_ref / us_new,
                       label_agreement=agree)
            _row(f"kmeans_reference_N{n}", us_ref, f"F={f} K={k}")
            derived = (f"speedup={us_ref / us_new:.1f}x "
                       f"label_agreement={agree:.3f}")
        _row(f"kmeans_fused_N{n}", us_new, derived)
        out[n] = row
    _save("clustering", out)
    top = out[max(out)]
    _summary("clustering", N=top["N"], fused_us=top["fused_us"],
             speedup=top.get("speedup"))


# ----------------------------------------------------------------------
# micro: selection / auction throughput
# ----------------------------------------------------------------------

def bench_selection(quick: bool):
    """Fused round control plane (repro.core.rounds.simulate_rounds — one
    lax.scan over T rounds of the full auction/energy dynamics, metrics
    buffered on device) vs the seed per-round Python path (eager
    select/reward/update with a host metric fetch every round) across an
    N sweep. The reference is capped: its per-round dispatch+sync
    overhead dominates long before N=1M; the fused path alone sweeps to
    a million clients."""
    from repro.configs.base import FLConfig
    from repro.core import rounds as R
    ns = [1000, 10_000] if quick else [10_000, 100_000, 1_000_000]
    ref_cap = 10_000 if quick else 100_000
    out = {}
    for n in ns:
        T = 16 if quick else (64 if n <= 100_000 else 16)
        cfg = FLConfig(num_clients=n, num_clusters=10, select_ratio=0.1,
                       scheme="gradient_cluster_auction",
                       init_energy_mode="normal")
        key = jax.random.PRNGKey(0)
        state = R.synthetic_fleet(cfg, key)
        kr = jax.random.fold_in(key, 1)

        def fused():
            fs, m, _ = R.simulate_rounds(state, cfg, kr, T)
            return m["energy_std"]

        # time the cold (compile+run) call separately so the reported
        # rounds/s is the warm throughput and compile cost is its own row
        t0 = time.time()
        jax.block_until_ready(fused())
        cold_s = time.time() - t0
        us_f = _t(fused, n=2 if n >= 1_000_000 else 3, warmup=0)
        compile_s = max(cold_s - us_f / 1e6, 0.0)
        fused_rps = T / (us_f / 1e6)
        row = {"N": n, "T": T, "fused_us_per_round": us_f / T,
               "fused_rounds_per_s": fused_rps, "compile_s": compile_s}
        derived = f"T={T} rounds_per_s={fused_rps:.1f} " \
                  f"compile_s={compile_s:.2f}"
        if n <= ref_cap:
            us_r = _t(lambda: R.simulate_rounds_reference(
                state, cfg, kr, T)[1]["energy_std"], n=1, warmup=1)
            ref_rps = T / (us_r / 1e6)
            row.update(ref_us_per_round=us_r / T,
                       ref_rounds_per_s=ref_rps,
                       speedup=us_r / us_f)
            _row(f"selection_rounds_ref_N{n}", us_r / T,
                 f"T={T} rounds_per_s={ref_rps:.1f}")
            derived += f" speedup={us_r / us_f:.1f}x"
        _row(f"selection_rounds_fused_N{n}", us_f / T, derived)
        out[n] = row
    _save("selection", out)
    top = out[max(out)]
    _summary("selection", N=top["N"], T=top["T"],
             warm_rounds_per_s=top["fused_rounds_per_s"],
             compile_s=top["compile_s"], speedup=top.get("speedup"))


# ----------------------------------------------------------------------
# micro: cohort execution engine (repro.sim)
# ----------------------------------------------------------------------

def bench_cohort_engine(quick: bool):
    """Sequential per-client loop vs the vectorized cohort engine
    (repro.sim) at several cohort sizes: one full cohort of local
    training + FedAvg aggregation per call, identical shuffles/batches
    in both backends."""
    from repro.configs.base import FLConfig
    from repro.core.adapters import cnn_adapter
    from repro.data.partition import partition_clients
    from repro.data.synthetic import make_image_dataset
    from repro.sim.runtime import make_runtime

    cohorts = [2, 4, 8, 16] if quick else [2, 4, 8, 16, 32, 64]
    nclients = max(cohorts)
    # near-uniform shards (~130 train samples -> 4 steps/client) keep the
    # comparison about execution, not about padding waste
    cfg = FLConfig(num_clients=nclients, num_clusters=1, local_epochs=1,
                   imbalance_low=0.9, imbalance_high=1.1, seed=0)
    train, _ = make_image_dataset("mnist", n_train=nclients * 165,
                                  n_test=64, seed=0)
    clients = partition_clients(train.y, cfg, seed=0)
    adapter = cnn_adapter("mnist")
    params = adapter.init(jax.random.PRNGKey(0))
    history = np.zeros((nclients,), np.int64)
    seq = make_runtime(cfg.replace(runtime="sequential"), adapter,
                       train.x, train.y, clients)
    vec = make_runtime(cfg.replace(runtime="vectorized"), adapter,
                       train.x, train.y, clients)
    out = {}
    for c in cohorts:
        sel = np.arange(c)
        us_s = _t(lambda: seq.train_cohort(params, sel, history),
                  n=3, warmup=1)
        us_v = _t(lambda: vec.train_cohort(params, sel, history),
                  n=3, warmup=1)
        speedup = us_s / us_v
        steps = sum((clients[i].size - min(32, clients[i].size))
                    // min(32, clients[i].size) + 1 for i in range(c))
        _row(f"cohort_engine_seq_C{c}", us_s, f"steps={steps}")
        _row(f"cohort_engine_vec_C{c}", us_v, f"speedup={speedup:.2f}x")
        out[c] = {"seq_us": us_s, "vec_us": us_v, "speedup": speedup}
    _save("cohort_engine", out)
    top = out[max(out)]
    _summary("cohort_engine", cohort=max(out), vec_us=top["vec_us"],
             speedup=top["speedup"])


# ----------------------------------------------------------------------
# micro: sharded cohort runtime (repro.sim, mesh-mapped stage-3)
# ----------------------------------------------------------------------

def bench_cohort_sharded(quick: bool):
    """Vectorized (1-device) vs sharded (mesh-mapped) cohort training on
    whatever devices this process sees.  On a plain host the cohort mesh
    degrades to 1 device (the bench then measures shard_map overhead);
    CI runs it under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    to exercise the real 8-way client-axis split + psum reduction.  Every
    row also checks the sharded aggregate against the vectorized one
    (same float-reassociation tolerance class as tests/test_sim.py)."""
    from repro.configs.base import FLConfig
    from repro.core.adapters import cnn_adapter
    from repro.data.partition import partition_clients
    from repro.data.synthetic import make_image_dataset
    from repro.sim.runtime import make_runtime

    n_dev = jax.local_device_count()
    cohorts = [8, 16] if quick else [8, 16, 32, 64]
    nclients = max(cohorts)
    cfg = FLConfig(num_clients=nclients, num_clusters=1, local_epochs=1,
                   imbalance_low=0.9, imbalance_high=1.1, seed=0)
    train, _ = make_image_dataset("mnist", n_train=nclients * 165,
                                  n_test=64, seed=0)
    clients = partition_clients(train.y, cfg, seed=0)
    adapter = cnn_adapter("mnist")
    params = adapter.init(jax.random.PRNGKey(0))
    history = np.zeros((nclients,), np.int64)
    vec = make_runtime(cfg.replace(runtime="vectorized"), adapter,
                       train.x, train.y, clients)
    shd = make_runtime(cfg.replace(runtime="sharded"), adapter,
                       train.x, train.y, clients)
    out = {"devices": n_dev}
    for c in cohorts:
        sel = np.arange(c)
        t0 = time.time()
        jax.block_until_ready(shd.train_cohort(params, sel, history))
        cold_s = time.time() - t0
        us_v = _t(lambda: vec.train_cohort(params, sel, history),
                  n=3, warmup=1)
        us_s = _t(lambda: shd.train_cohort(params, sel, history),
                  n=3, warmup=0)
        p_v = vec.train_cohort(params, sel, history)
        p_s = shd.train_cohort(params, sel, history)
        diff = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), p_v, p_s)))
        assert diff < 1e-4, f"sharded drifted from vectorized: {diff}"
        speedup = us_v / us_s
        _row(f"cohort_sharded_vec_C{c}", us_v, "devices=1")
        _row(f"cohort_sharded_shd_C{c}", us_s,
             f"devices={n_dev} speedup={speedup:.2f}x "
             f"max_diff={diff:.1e} compile_s={cold_s - us_s / 1e6:.2f}")
        out[c] = {"vec_us": us_v, "sharded_us": us_s, "speedup": speedup,
                  "max_param_diff": diff,
                  "compile_s": max(cold_s - us_s / 1e6, 0.0)}
    _save("cohort_sharded", out)
    big = max(c for c in out if isinstance(c, int))
    _summary("cohort_sharded", devices=n_dev, cohort=big,
             sharded_us=out[big]["sharded_us"],
             speedup=out[big]["speedup"])


# ----------------------------------------------------------------------
# micro: end-to-end round pipeline (host-packed vs device-resident)
# ----------------------------------------------------------------------

def bench_round_pipeline(quick: bool):
    """Warm end-to-end FL rounds/sec: host-packed ``vectorized`` vs the
    device-resident ``device`` runtime on the full server loop (stage-2
    control plane + stage-3 training + async metric buffering), with the
    per-round cost split into ``host_pack_s`` (numpy gather / index
    assembly) and ``device_s`` (everything else: dispatch + compute +
    any retraces).  The fleet is imbalanced and the scheme picks a fresh
    random cohort each round, so the vectorized packer keeps meeting new
    bucket shapes — the realistic regime the capacity-class policy is
    built for; retrace/hit counters from ``engine.stats`` make the
    "zero retraces after warm-up" claim auditable in the JSON."""
    from repro.configs.base import FLConfig
    from repro.core.adapters import cnn_adapter
    from repro.core.server import FederatedServer
    from repro.data.partition import partition_clients
    from repro.data.synthetic import make_image_dataset

    nclients = 24 if quick else 64
    warm_rounds, timed_rounds = (2, 5) if quick else (3, 8)
    # the paper's own scheme: eligibility thresholds + per-cluster
    # auctions make the winner count AND composition shift round to
    # round, the regime where data-dependent bucket shapes keep the
    # host-packed path tracing; local_epochs=2 widens the step bands.
    cfg = FLConfig(num_clients=nclients, num_clusters=4,
                   select_ratio=10 / nclients if quick else 0.25,
                   local_epochs=2, scheme="gradient_cluster_auction",
                   sample_window=20, cluster_resamples=2,
                   init_energy_mode="normal", eval_every=10 ** 6, seed=0)
    train, test = make_image_dataset("mnist", n_train=nclients * 130,
                                     n_test=256, seed=0)
    adapter = cnn_adapter("mnist")
    cohort = max(int(round(cfg.select_ratio * nclients)), 1)
    out = {"cohort": cohort, "clients": nclients,
           "warm_rounds": warm_rounds, "timed_rounds": timed_rounds}
    for rt in ("vectorized", "device"):
        clients = partition_clients(train.y, cfg, seed=0)
        srv = FederatedServer(cfg.replace(runtime=rt), adapter, train.x,
                              train.y, clients,
                              {"x": test.x[:256], "y": test.y[:256]})
        # warm-up: stage-1 clustering + device-runtime class compiles +
        # the first rounds' programs — all outside the timed window
        srv.run(rounds=warm_rounds)
        jax.block_until_ready(srv.params)
        stats0 = dict(srv.runtime.engine.stats)
        srv.runtime.host_pack_s = 0.0
        t0 = time.time()
        for t in range(warm_rounds, warm_rounds + timed_rounds):
            srv._dispatch_round(t, eval_now=False)   # the round pipeline
        srv._flush_pending()
        jax.block_until_ready(srv.params)
        wall = time.time() - t0
        stats1 = srv.runtime.engine.stats
        row = {
            "rounds_per_s": timed_rounds / wall,
            "host_pack_s": srv.runtime.host_pack_s,
            "device_s": wall - srv.runtime.host_pack_s,
            "retraces_warm": stats1["traces"] - stats0["traces"],
            "new_shapes_warm": (stats1["shape_misses"]
                                - stats0["shape_misses"]),
        }
        out[rt] = row
        _row(f"round_pipeline_{rt}", wall / timed_rounds * 1e6,
             f"cohort={cohort} rounds_per_s={row['rounds_per_s']:.2f} "
             f"host_pack_s={row['host_pack_s']:.3f} "
             f"retraces_warm={row['retraces_warm']}")
    out["speedup"] = (out["device"]["rounds_per_s"]
                      / out["vectorized"]["rounds_per_s"])
    _row("round_pipeline_speedup", 0.0,
         f"device_vs_vectorized={out['speedup']:.2f}x")
    _save("round_pipeline", out)
    _summary("round_pipeline", cohort=cohort, clients=nclients,
             warm_rounds_per_s=out["device"]["rounds_per_s"],
             vectorized_rounds_per_s=out["vectorized"]["rounds_per_s"],
             retraces_warm=out["device"]["retraces_warm"],
             speedup=out["speedup"])


def bench_fleet_dynamics(quick: bool):
    """Fleet-dynamics overhead + robustness: warm FL rounds/sec and test
    accuracy at dropout rates 0 / 0.1 / 0.3 (deadline + buffered
    aggregation on for the faulty fleets).  The rate-0 row runs the
    dynamics-free bit-exact path, so the delta to rate>0 rows is the
    full price of the fault model (fused fault step + outcome fetch +
    replacement sampling + buffer folds)."""
    from repro.configs.base import FLConfig
    from repro.core.adapters import cnn_adapter
    from repro.core.server import FederatedServer
    from repro.data.partition import partition_clients
    from repro.data.synthetic import make_image_dataset

    nclients = 24 if quick else 64
    warm_rounds, timed_rounds = (2, 4) if quick else (3, 8)
    base = FLConfig(num_clients=nclients, num_clusters=4,
                    select_ratio=10 / nclients if quick else 0.25,
                    local_epochs=2, scheme="gradient_cluster_auction",
                    sample_window=20, cluster_resamples=2,
                    init_energy_mode="normal", eval_every=10 ** 6,
                    runtime="device", seed=0)
    train, test = make_image_dataset("mnist", n_train=nclients * 130,
                                     n_test=256, seed=0)
    adapter = cnn_adapter("mnist")
    out = {"clients": nclients, "warm_rounds": warm_rounds,
           "timed_rounds": timed_rounds, "rates": {}}
    for rate in (0.0, 0.1, 0.3):
        cfg = base.replace(
            churn=rate, deadline=1.5 if rate > 0 else 0.0,
            aggregation="buffered" if rate > 0 else "sync")
        clients = partition_clients(train.y, cfg, seed=0)
        srv = FederatedServer(cfg, adapter, train.x, train.y, clients,
                              {"x": test.x[:256], "y": test.y[:256]})
        srv.run(rounds=warm_rounds)
        jax.block_until_ready(srv.params)
        t0 = time.time()
        for t in range(warm_rounds, warm_rounds + timed_rounds):
            srv._dispatch_round(t, eval_now=False)
        srv._flush_pending()
        jax.block_until_ready(srv.params)
        wall = time.time() - t0
        acc, _ = jax.device_get(srv._eval_step(srv.params, srv._test_dev))
        codes = (np.concatenate(srv.outcome_log) if srv.dynamics
                 else np.zeros((0,), np.int32))
        row = {
            "rounds_per_s": timed_rounds / wall,
            "test_acc": float(acc),
            "num_late": int((codes == 2).sum()),
            "num_dropped": int((codes == 3).sum()),
        }
        out["rates"][str(rate)] = row
        _row(f"fleet_dynamics_p{rate}", wall / timed_rounds * 1e6,
             f"rounds_per_s={row['rounds_per_s']:.2f} "
             f"acc={row['test_acc']:.3f} late={row['num_late']} "
             f"dropped={row['num_dropped']}")
    base_rps = out["rates"]["0.0"]["rounds_per_s"]
    out["overhead_p0.3"] = base_rps / out["rates"]["0.3"]["rounds_per_s"]
    _save("fleet_dynamics", out)
    _summary("fleet_dynamics", clients=nclients,
             rounds_per_s_p0=base_rps,
             rounds_per_s_p01=out["rates"]["0.1"]["rounds_per_s"],
             rounds_per_s_p03=out["rates"]["0.3"]["rounds_per_s"],
             acc_p0=out["rates"]["0.0"]["test_acc"],
             acc_p01=out["rates"]["0.1"]["test_acc"],
             acc_p03=out["rates"]["0.3"]["test_acc"],
             overhead_p03=out["overhead_p0.3"])


def bench_scheme_zoo(quick: bool):
    """Scheme x Non-IID benchmark matrix over the pluggable round
    control plane (repro.core.schemes): every registered selection
    scheme runs the SAME fused round programs on the device runtime, so
    the cells differ only in who gets selected.  Per cell: warm FL
    rounds/sec (the scheme dispatch must not cost throughput — every
    scheme compiles into the one lax.scan/step program), final test
    accuracy (convergence), final residual-energy std (the paper's
    energy-balance fairness, Fig 9/10) and the participation-history
    std (selection fairness).  The long-term auction additionally
    reports its budget ledger (total spend vs the Rg cap)."""
    from repro.configs.base import FLConfig
    from repro.core.adapters import cnn_adapter
    from repro.core.server import FederatedServer
    from repro.data.partition import partition_clients
    from repro.data.synthetic import make_image_dataset

    zoo = ("paper", "random", "fedcs", "longterm_auction")
    nclients = 24 if quick else 50
    warm_rounds, timed_rounds = (2, 4) if quick else (3, 8)
    rounds = 6 if quick else 30
    nus = (1.0,) if quick else (1.0, 0.5)
    base = FLConfig(num_clients=nclients, num_clusters=4,
                    select_ratio=0.25, local_epochs=1,
                    scheme="gradient_cluster_auction",
                    sample_window=20, cluster_resamples=2,
                    init_energy_mode="normal", eval_every=10 ** 6,
                    runtime="device", seed=0)
    train, test = make_image_dataset("mnist", n_train=nclients * 125,
                                     n_test=256, seed=0)
    adapter = cnn_adapter("mnist")
    out = {"clients": nclients, "rounds": rounds,
           "warm_rounds": warm_rounds, "timed_rounds": timed_rounds,
           "cells": {}}
    for nu in nus:
        for scheme in zoo:
            cfg = base.replace(non_iid_level=nu, scheme_select=scheme)
            clients = partition_clients(train.y, cfg, seed=0)
            srv = FederatedServer(cfg, adapter, train.x, train.y, clients,
                                  {"x": test.x[:256], "y": test.y[:256]})
            srv.run(rounds=warm_rounds)
            jax.block_until_ready(srv.params)
            t0 = time.time()
            for t in range(warm_rounds, warm_rounds + timed_rounds):
                srv._dispatch_round(t, eval_now=False)
            srv._flush_pending()
            jax.block_until_ready(srv.params)
            wall = time.time() - t0
            for t in range(warm_rounds + timed_rounds, rounds):
                srv._dispatch_round(t, eval_now=False)
            srv._flush_pending()
            acc, _ = jax.device_get(
                srv._eval_step(srv.params, srv._test_dev))
            hist = np.asarray(jax.device_get(srv.state.history))
            row = {
                "rounds_per_s": timed_rounds / wall,
                "test_acc": float(acc),
                "energy_std": float(srv.logs[-1].energy_std),
                "fairness_hist_std": float(np.std(hist)),
            }
            if scheme == "longterm_auction":
                ss = srv.state.scheme_state
                row["budget_spent_total"] = float(
                    jax.device_get(ss.spent))
                row["budget_queue_final"] = float(
                    jax.device_get(ss.queue))
            out["cells"][f"{scheme}_nu{nu}"] = row
            _row(f"scheme_zoo_{scheme}_nu{nu}",
                 wall / timed_rounds * 1e6,
                 f"rounds_per_s={row['rounds_per_s']:.2f} "
                 f"acc={row['test_acc']:.3f} "
                 f"energy_std={row['energy_std']:.3f} "
                 f"fairness={row['fairness_hist_std']:.2f}")
    _save("scheme_zoo", out)
    c = out["cells"]
    _summary("scheme_zoo", clients=nclients, rounds=rounds,
             warm_rounds_per_s_paper=c["paper_nu1.0"]["rounds_per_s"],
             **{f"acc_{s}": c[f"{s}_nu1.0"]["test_acc"] for s in zoo},
             **{f"energy_std_{s}": c[f"{s}_nu1.0"]["energy_std"]
                for s in zoo},
             **{f"fairness_{s}": c[f"{s}_nu1.0"]["fairness_hist_std"]
                for s in zoo},
             budget_spent=c["longterm_auction_nu1.0"]
             ["budget_spent_total"])


def bench_robust_agg(quick: bool):
    """Byzantine robustness + defended-aggregation overhead: final test
    accuracy and warm FL rounds/sec across adversary fraction 0 / 0.1 /
    0.3 x defense off (plain FedAvg) / on (screened trimmed-mean), scale
    attack, device runtime.  The (0, off) cell is the attack-free
    bit-exact baseline; (0, on) prices the screened path on a clean
    fleet (~4% warm rounds/sec: per-client delta materialization + the
    sort-based screen); the 0.3 column is the headline: undefended
    FedAvg degrades while the screened aggregation recovers to within
    ~2 points of the attack-free accuracy."""
    from repro.configs.base import FLConfig
    from repro.core.adapters import cnn_adapter
    from repro.core.server import FederatedServer
    from repro.data.partition import partition_clients
    from repro.data.synthetic import make_image_dataset

    nclients = 24 if quick else 32
    # a wide timed window amortizes host timing jitter: the overhead
    # headline compares two separately-timed runs, so per-window noise
    # must be well under the <2% claim it prices
    warm_rounds, timed_rounds = (2, 4) if quick else (5, 20)
    # full mode runs to convergence: the clean baseline reaches ~0.99 by
    # round 60 under this lr/nu, so the 0.3-adversary column separates
    # (undefended collapses to chance, screened recovers within ~2 pts)
    rounds = 6 if quick else 60
    base = FLConfig(num_clients=nclients, num_clusters=4,
                    select_ratio=0.3, local_epochs=2, lr=0.1,
                    non_iid_level=0.3,
                    scheme="gradient_cluster_auction",
                    sample_window=20, cluster_resamples=2,
                    init_energy_mode="normal", eval_every=10 ** 6,
                    runtime="device", attack="scale", seed=0)
    train, test = make_image_dataset("mnist", n_train=nclients * 150,
                                     n_test=256, seed=0)
    adapter = cnn_adapter("mnist")
    out = {"clients": nclients, "rounds": rounds,
           "warm_rounds": warm_rounds, "timed_rounds": timed_rounds,
           "attack": "scale", "cells": {}}
    for frac in (0.0, 0.1, 0.3):
        for defense in ("none", "trimmed"):
            cfg = base.replace(adversary_frac=frac, defense=defense)
            clients = partition_clients(train.y, cfg, seed=0)
            srv = FederatedServer(cfg, adapter, train.x, train.y, clients,
                                  {"x": test.x[:256], "y": test.y[:256]})
            srv.run(rounds=warm_rounds)
            jax.block_until_ready(srv.params)
            t0 = time.time()
            for t in range(warm_rounds, warm_rounds + timed_rounds):
                srv._dispatch_round(t, eval_now=False)
            srv._flush_pending()
            jax.block_until_ready(srv.params)
            wall = time.time() - t0
            for t in range(warm_rounds + timed_rounds, rounds):
                srv._dispatch_round(t, eval_now=False)
            srv._flush_pending()
            acc, _ = jax.device_get(
                srv._eval_step(srv.params, srv._test_dev))
            row = {"rounds_per_s": timed_rounds / wall,
                   "test_acc": float(acc)}
            if srv.defended:
                row.update(srv.defense_totals)
            out["cells"][f"frac{frac}_{defense}"] = row
            _row(f"robust_agg_f{frac}_{defense}",
                 wall / timed_rounds * 1e6,
                 f"rounds_per_s={row['rounds_per_s']:.2f} "
                 f"acc={row['test_acc']:.3f}")
    cells = out["cells"]
    clean = cells["frac0.0_none"]
    out["overhead_defended"] = (clean["rounds_per_s"]
                                / cells["frac0.0_trimmed"]["rounds_per_s"]
                                - 1.0)
    out["attack_drop_0.3"] = (clean["test_acc"]
                              - cells["frac0.3_none"]["test_acc"])
    out["defended_gap_0.3"] = (clean["test_acc"]
                               - cells["frac0.3_trimmed"]["test_acc"])
    _row("robust_agg_summary", 0.0,
         f"overhead={out['overhead_defended'] * 100:.1f}% "
         f"attack_drop={out['attack_drop_0.3']:.3f} "
         f"defended_gap={out['defended_gap_0.3']:.3f}")
    _save("robust_agg", out)
    _summary("robust_agg", clients=nclients, rounds=rounds,
             acc_clean=clean["test_acc"],
             acc_attacked_undefended=cells["frac0.3_none"]["test_acc"],
             acc_attacked_defended=cells["frac0.3_trimmed"]["test_acc"],
             acc_f01_undefended=cells["frac0.1_none"]["test_acc"],
             acc_f01_defended=cells["frac0.1_trimmed"]["test_acc"],
             warm_rounds_per_s_clean=clean["rounds_per_s"],
             warm_rounds_per_s_defended=cells["frac0.0_trimmed"]
             ["rounds_per_s"],
             overhead_defended=out["overhead_defended"],
             attack_drop=out["attack_drop_0.3"],
             defended_gap=out["defended_gap_0.3"])


# ----------------------------------------------------------------------
# macro: self-healing server (ISSUE 10 acceptance run)
# ----------------------------------------------------------------------

def bench_self_healing(quick: bool):
    """The self-healing acceptance comparison: a sub_clip adversary
    coalition (30% of the fleet, colluding just under the static clip
    threshold) against (a) no defense at all, (b) the static clip — it
    never touches a sub-threshold row, so accuracy measurably degrades —
    and (c) the full self-healing stack: adaptive MAD-band screening +
    reputation-priced bidding + the divergence watchdog.  The headline
    is ``selfheal_gap`` (within 0.05 of the clean baseline in the full
    60-round run) vs ``static_gap``; ``watchdog_overhead`` prices the
    watchdog's warm-loop hooks (delta scaling + snapshot refs) on a
    clean run."""
    from repro.configs.base import FLConfig
    from repro.core.adapters import cnn_adapter
    from repro.core.server import FederatedServer
    from repro.data.partition import partition_clients
    from repro.data.synthetic import make_image_dataset

    nclients = 24 if quick else 32
    warm_rounds, timed_rounds = (2, 4) if quick else (5, 20)
    rounds = 6 if quick else 60
    eval_every = 3 if quick else 10
    base = FLConfig(num_clients=nclients, num_clusters=4,
                    select_ratio=0.3, local_epochs=2, lr=0.1,
                    non_iid_level=0.3,
                    scheme="gradient_cluster_auction",
                    sample_window=20, cluster_resamples=2,
                    init_energy_mode="normal", eval_every=eval_every,
                    runtime="device", seed=0)
    train, test = make_image_dataset("mnist", n_train=nclients * 150,
                                     n_test=256, seed=0)
    adapter = cnn_adapter("mnist")
    clients = partition_clients(train.y, base, seed=0)

    def cell(label, **kw):
        cfg = base.replace(**kw)
        srv = FederatedServer(cfg, adapter, train.x, train.y, clients,
                              {"x": test.x[:256], "y": test.y[:256]})
        srv.run(rounds=warm_rounds)
        jax.block_until_ready(srv.params)
        t0 = time.time()
        for t in range(warm_rounds, warm_rounds + timed_rounds):
            srv._dispatch_round(t, eval_now=False)
        srv._flush_pending()
        jax.block_until_ready(srv.params)
        wall = time.time() - t0
        for t in range(warm_rounds + timed_rounds, rounds):
            due = t % eval_every == 0 or t == rounds - 1
            srv._dispatch_round(t, eval_now=due)
            if due and cfg.watchdog_enabled:
                srv._flush_pending()       # watchdog detection boundary
        srv._flush_pending()
        acc, _ = jax.device_get(srv._eval_step(srv.params, srv._test_dev))
        row = {"rounds_per_s": timed_rounds / wall, "test_acc": float(acc)}
        if srv.defended:
            row.update(srv.defense_totals)
        if cfg.watchdog_enabled:
            row.update(srv.watchdog_totals)
        _row(f"self_healing_{label}", wall / timed_rounds * 1e6,
             f"rounds_per_s={row['rounds_per_s']:.2f} "
             f"acc={row['test_acc']:.3f}")
        return row

    atk = dict(attack="sub_clip", adversary_frac=0.3)
    out = {"clients": nclients, "rounds": rounds, "attack": "sub_clip",
           "adversary_frac": 0.3, "cells": {}}
    out["cells"]["clean"] = cell("clean")
    out["cells"]["clean_watchdog"] = cell("clean_watchdog", watchdog="on")
    out["cells"]["undefended"] = cell("undefended", **atk)
    out["cells"]["static_clip"] = cell("static_clip", defense="clip",
                                       **atk)
    out["cells"]["selfheal"] = cell(
        "selfheal", defense="clip", defense_mode="adaptive",
        reputation_mode="price", watchdog="on", **atk)

    cells = out["cells"]
    clean = cells["clean"]
    out["static_gap"] = clean["test_acc"] - cells["static_clip"]["test_acc"]
    out["selfheal_gap"] = clean["test_acc"] - cells["selfheal"]["test_acc"]
    out["watchdog_overhead"] = (
        clean["rounds_per_s"]
        / cells["clean_watchdog"]["rounds_per_s"] - 1.0)
    _row("self_healing_summary", 0.0,
         f"static_gap={out['static_gap']:.3f} "
         f"selfheal_gap={out['selfheal_gap']:.3f} "
         f"wd_overhead={out['watchdog_overhead'] * 100:.1f}%")
    _save("self_healing", out)
    _summary("self_healing", clients=nclients, rounds=rounds,
             acc_clean=clean["test_acc"],
             acc_attacked_undefended=cells["undefended"]["test_acc"],
             acc_attacked_static_clip=cells["static_clip"]["test_acc"],
             acc_attacked_selfheal=cells["selfheal"]["test_acc"],
             static_gap=out["static_gap"],
             selfheal_gap=out["selfheal_gap"],
             selfheal_within_005=bool(out["selfheal_gap"] <= 0.05),
             rollbacks_selfheal=cells["selfheal"].get("rollbacks", 0),
             screened_selfheal=cells["selfheal"].get("screened", 0),
             warm_rounds_per_s_clean=clean["rounds_per_s"],
             warm_rounds_per_s_selfheal=cells["selfheal"]["rounds_per_s"],
             watchdog_overhead=out["watchdog_overhead"])


# ----------------------------------------------------------------------
# paper figures (FL simulations)
# ----------------------------------------------------------------------

def _fl_run(scheme, nu, aggregator, rounds, quick, seed=0, dataset="mnist"):
    from repro.configs.base import FLConfig
    from repro.core.adapters import cnn_adapter
    from repro.core.server import FederatedServer
    from repro.data.partition import partition_clients
    from repro.data.synthetic import make_image_dataset
    nclients = 30 if quick else 100
    pool = 3000 if quick else 12_000
    cfg = FLConfig(num_clients=nclients, num_clusters=5 if quick else 10,
                   select_ratio=0.1, rounds=rounds, lr=0.05,
                   non_iid_level=nu, scheme=scheme, aggregator=aggregator,
                   init_energy_mode="normal",
                   sample_window=30 if quick else 50,
                   cluster_resamples=3 if quick else 5, seed=seed)
    train, test = make_image_dataset(dataset, n_train=pool,
                                     n_test=pool // 6, seed=seed)
    clients = partition_clients(train.y, cfg, seed=seed)
    srv = FederatedServer(cfg, cnn_adapter(dataset), train.x, train.y,
                          clients, {"x": test.x[:500], "y": test.y[:500]})
    logs = srv.run()
    return {
        "acc": [l.test_acc for l in logs],
        "loss": [l.test_loss for l in logs],
        "energy_std": [l.energy_std for l in logs],
        "mean_bid": [l.mean_bid for l in logs],
        "server_reward": [l.server_reward for l in logs],
        "client_reward_sum": [l.client_reward_sum for l in logs],
        "vds_gap": [l.vds_gap for l in logs],
    }


SCHEMES = {
    "Gradient-Cluster-Auction": "gradient_cluster_auction",
    "Gradient-Cluster-Random": "gradient_cluster_random",
    "Weights-Cluster-Random": "weights_cluster_random",
    "Random": "random",
}


def bench_fig4(quick: bool):
    """Fig 4: accuracy/loss vs rounds — gradient vs weights clustering vs
    random FedAvg (nu=1, imbalanced)."""
    rounds = 8 if quick else 30
    out = {}
    for label in ("Gradient-Cluster-Random", "Weights-Cluster-Random",
                  "Random"):
        t0 = time.time()
        r = _fl_run(SCHEMES[label], 1.0, "fedavg", rounds, quick)
        out[label] = r
        _row(f"fig4_{label}", (time.time() - t0) * 1e6 / rounds,
             f"final_acc={r['acc'][-1]:.3f} final_loss={r['loss'][-1]:.3f}")
    _save("fig4_convergence", out)


def bench_fig5(quick: bool):
    """Fig 5: price (mean winning bid) and reward vs rounds (reward model 2,
    eq 16)."""
    rounds = 8 if quick else 30
    t0 = time.time()
    r = _fl_run("gradient_cluster_auction", 1.0, "fedavg", rounds, quick)
    _row("fig5_price_reward", (time.time() - t0) * 1e6 / rounds,
         f"bid_first={r['mean_bid'][0]:.3f} bid_last={r['mean_bid'][-1]:.3f}"
         f" server_reward_last={r['server_reward'][-1]:.3f}")
    _save("fig5_price_reward", r)


def bench_fig6_7_8(quick: bool, aggregator: str = "fedavg"):
    """Fig 6 (Avg) / 7 (Prox) / 8 (nu=0.5): accuracy vs rounds for the
    schemes at nu in {1, 0.8, 0.5}."""
    rounds = 8 if quick else 30
    nus = [1.0] if quick else [1.0, 0.8, 0.5]
    out = {}
    for nu in nus:
        for label, scheme in SCHEMES.items():
            if label == "Weights-Cluster-Random":
                continue   # fig6-8 compare the other three
            t0 = time.time()
            r = _fl_run(scheme, nu, aggregator, rounds, quick)
            out[f"{label}_nu{nu}"] = r
            _row(f"fig6_{aggregator}_nu{nu}_{label}",
                 (time.time() - t0) * 1e6 / rounds,
                 f"final_acc={r['acc'][-1]:.3f}")
    _save(f"fig6_8_accuracy_{aggregator}", out)


def bench_fig9_10(quick: bool):
    """Fig 9/10: energy-balance std vs rounds, all schemes. Needs enough
    rounds for selection pressure to differentiate the schemes (the paper
    runs 100+)."""
    rounds = 8 if quick else 60
    out = {}
    for label, scheme in SCHEMES.items():
        t0 = time.time()
        r = _fl_run(scheme, 1.0, "fedavg", rounds, quick)
        out[label] = r["energy_std"]
        _row(f"fig9_energy_{label}", (time.time() - t0) * 1e6 / rounds,
             f"final_energy_std={r['energy_std'][-1]:.3f}")
    _save("fig9_energy_balance", out)


def bench_virtual_dataset(quick: bool):
    """Fig 3 concept: TV distance of the round virtual dataset from the
    global distribution, cluster selection vs random."""
    rounds = 10 if quick else 30
    gaps = {}
    for label in ("Gradient-Cluster-Random", "Random"):
        r = _fl_run(SCHEMES[label], 1.0, "fedavg", rounds, quick)
        gaps[label] = float(np.mean(r["vds_gap"]))
    _row("fig3_vds_gap", 0.0,
         f"cluster={gaps['Gradient-Cluster-Random']:.3f} "
         f"random={gaps['Random']:.3f}")
    _save("fig3_vds_gap", gaps)


BENCHES = {
    "kernels": bench_kernels,
    "clustering": bench_clustering,
    "selection": bench_selection,
    "cohort_engine": bench_cohort_engine,
    "cohort_sharded": bench_cohort_sharded,
    "round_pipeline": bench_round_pipeline,
    "fleet_dynamics": bench_fleet_dynamics,
    "robust_agg": bench_robust_agg,
    "self_healing": bench_self_healing,
    "scheme_zoo": bench_scheme_zoo,
    "fig3": bench_virtual_dataset,
    "fig4": bench_fig4,
    "fig5": bench_fig5,
    "fig6": lambda q: bench_fig6_7_8(q, "fedavg"),
    "fig7": lambda q: bench_fig6_7_8(q, "fedprox"),
    "fig9": bench_fig9_10,
}


def main() -> None:
    from repro import obs
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help=f"comma list of {list(BENCHES)}")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the selected "
                         "benchmarks for TensorBoard/Perfetto")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    with obs.maybe_profile(args.profile_dir):
        for n in names:
            BENCHES[n](args.quick)


if __name__ == "__main__":
    main()
