"""Quickstart: auction-based clustered federated learning in ~40 lines.

Runs the paper's full pipeline (gradient clustering -> per-cluster auction
-> FedAvg) with 30 edge clients on a synthetic non-IID MNIST-like dataset.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.base import FLConfig
from repro.core.adapters import cnn_adapter
from repro.core.server import FederatedServer
from repro.data.partition import partition_clients
from repro.data.synthetic import make_image_dataset


def main():
    cfg = FLConfig(
        num_clients=30,                 # N edge clients
        num_clusters=5,                 # J gradient clusters
        select_ratio=0.2,               # K/N selected per round
        rounds=15,
        non_iid_level=1.0,              # nu = 1: fully non-IID
        scheme="gradient_cluster_auction",
        init_energy_mode="normal",      # case 2: heterogeneous batteries
    )
    train, test = make_image_dataset("mnist", n_train=4000, n_test=800)
    clients = partition_clients(train.y, cfg, seed=0)
    print(f"{cfg.num_clients} clients, local sizes "
          f"{min(c.size for c in clients)}..{max(c.size for c in clients)}")

    server = FederatedServer(cfg, cnn_adapter("mnist"), train.x, train.y,
                             clients, {"x": test.x, "y": test.y})
    logs = server.run(verbose=True)

    print("\ncluster assignment of the 30 clients (primary label = i % 10):")
    print(np.asarray(server.state.clusters).reshape(3, 10))
    print(f"\nfinal test accuracy : {logs[-1].test_acc:.3f}")
    print(f"energy-balance std  : {logs[-1].energy_std:.3f}")
    print(f"mean winning bid    : {logs[-1].mean_bid:.3f}")


if __name__ == "__main__":
    main()
