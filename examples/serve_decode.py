"""Serve a small model with batched greedy decoding (KV / recurrent caches),
including the int8-quantized KV cache option — the serving side of the
framework that the decode dry-run shapes exercise at full scale.

  PYTHONPATH=src python examples/serve_decode.py --arch xlstm-1.3b
  PYTHONPATH=src python examples/serve_decode.py --arch starcoder2-3b \
      --kv-dtype int8
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.launch.steps import make_serve_step
from repro.models import model as MD


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--kv-dtype", default="auto",
                    choices=["auto", "bfloat16", "int8"])
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).replace(kv_cache_dtype=args.kv_dtype)
    params = MD.init_params(cfg, jax.random.PRNGKey(0))
    B = args.batch
    state = MD.init_decode_state(cfg, B, args.gen + 8)
    if cfg.is_encdec:
        frames = jax.random.normal(jax.random.PRNGKey(1),
                                   (B, cfg.encoder_seq, cfg.d_model))
        state["cross"] = MD.build_cross_cache(
            cfg, params, MD.encode(cfg, params, frames))
    step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    tok = jnp.zeros((B,), jnp.int32)
    toks = []
    t0 = time.time()
    for t in range(args.gen):
        tok, state = step(params, state, tok, jnp.int32(t))
        toks.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"{cfg.name}: {B}x{args.gen} tokens in {dt:.2f}s "
          f"({B*args.gen/dt:.0f} tok/s, kv={args.kv_dtype})")
    print("first sequence:", [int(t[0]) for t in toks][:12], "...")


if __name__ == "__main__":
    main()
