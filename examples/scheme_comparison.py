"""End-to-end driver: train the paper's CNN federatedly for a few hundred
aggregate local steps under all four selection schemes and compare the
paper's three headline metrics (convergence, energy balance, virtual-dataset
gap) — the Figs 6/9 experiment at reduced scale.

  PYTHONPATH=src python examples/scheme_comparison.py [--rounds 20]
"""
import argparse

import numpy as np

from repro.configs.base import FLConfig
from repro.core.adapters import cnn_adapter
from repro.core.server import FederatedServer
from repro.data.partition import partition_clients
from repro.data.synthetic import make_image_dataset

SCHEMES = [
    ("Gradient-Cluster-Auction", "gradient_cluster_auction"),
    ("Gradient-Cluster-Random", "gradient_cluster_random"),
    ("Random-FedAvg", "random"),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--nu", type=float, default=1.0)
    args = ap.parse_args()

    train, test = make_image_dataset("mnist", n_train=6000, n_test=1000)
    print(f"{'scheme':28s} {'acc':>6s} {'loss':>7s} {'E_std':>7s} "
          f"{'vds_gap':>8s}")
    for label, scheme in SCHEMES:
        cfg = FLConfig(num_clients=50, num_clusters=10, select_ratio=0.2,
                       rounds=args.rounds, non_iid_level=args.nu,
                       scheme=scheme, init_energy_mode="normal", seed=1)
        clients = partition_clients(train.y, cfg, seed=1)
        srv = FederatedServer(cfg, cnn_adapter("mnist"), train.x, train.y,
                              clients, {"x": test.x, "y": test.y})
        logs = srv.run()
        print(f"{label:28s} {logs[-1].test_acc:6.3f} "
              f"{logs[-1].test_loss:7.3f} {logs[-1].energy_std:7.3f} "
              f"{np.mean([l.vds_gap for l in logs]):8.3f}")


if __name__ == "__main__":
    main()
