"""Selection-scheme comparison matrix: train the paper's CNN
federatedly under every scheme in the control-plane registry
(repro.core.schemes — paper auction, uniform random, FedCS
deadline-gating, long-term budgeted auction) x Non-IID level, and
compare convergence (test accuracy/loss) against the two fairness
axes the zoo trades off: residual-energy balance (the paper's Fig 9/10
energy std) and participation spread (history std).  The long-term
auction also prints its budget ledger.  The full-size version of this
matrix is the ``scheme_zoo`` benchmark (``python -m benchmarks.run
--only scheme_zoo`` -> BENCH_scheme_zoo.json).

  PYTHONPATH=src python examples/scheme_comparison.py [--rounds 20]
  PYTHONPATH=src python examples/scheme_comparison.py --nus 1.0 0.5
"""
import argparse

import numpy as np

from repro.configs.base import FLConfig
from repro.core.adapters import cnn_adapter
from repro.core.schemes import scheme_names
from repro.core.server import FederatedServer
from repro.data.partition import partition_clients
from repro.data.synthetic import make_image_dataset


def run_cell(scheme_select, nu, rounds, train, test):
    cfg = FLConfig(num_clients=50, num_clusters=10, select_ratio=0.2,
                   rounds=rounds, non_iid_level=nu,
                   scheme="gradient_cluster_auction",
                   scheme_select=scheme_select,
                   init_energy_mode="normal", seed=1)
    clients = partition_clients(train.y, cfg, seed=1)
    srv = FederatedServer(cfg, cnn_adapter("mnist"), train.x, train.y,
                          clients, {"x": test.x, "y": test.y})
    logs = srv.run()
    hist = np.asarray([int(h) for h in srv._host_history])
    row = {
        "acc": logs[-1].test_acc,
        "loss": logs[-1].test_loss,
        "energy_std": logs[-1].energy_std,
        "fairness": float(np.std(hist)),
        "vds_gap": float(np.mean([l.vds_gap for l in logs])),
    }
    ss = srv.state.scheme_state
    if ss is not None:
        row["budget"] = (f"{float(np.asarray(ss.spent)):.2f}"
                         f"/{cfg.total_reward:.0f}")
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--nus", type=float, nargs="+", default=[1.0])
    args = ap.parse_args()

    train, test = make_image_dataset("mnist", n_train=6000, n_test=1000)
    print(f"{'scheme':18s} {'nu':>4s} {'acc':>6s} {'loss':>7s} "
          f"{'E_std':>7s} {'fair':>6s} {'vds_gap':>8s} {'budget':>12s}")
    for nu in args.nus:
        for scheme in scheme_names():
            r = run_cell(scheme, nu, args.rounds, train, test)
            print(f"{scheme:18s} {nu:4.1f} {r['acc']:6.3f} "
                  f"{r['loss']:7.3f} {r['energy_std']:7.3f} "
                  f"{r['fairness']:6.2f} {r['vds_gap']:8.3f} "
                  f"{r.get('budget', '-'):>12s}")


if __name__ == "__main__":
    main()
