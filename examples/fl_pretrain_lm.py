"""Federated pretraining of a (reduced) registry transformer — the paper's
selection layer applied to an LLM workload: clients hold topic-skewed token
shards; gradient clustering groups clients by topic; the auction balances
energy across the fleet.

  PYTHONPATH=src python examples/fl_pretrain_lm.py --arch qwen2-0.5b
"""
import argparse

from repro.configs.base import FLConfig
from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.core.adapters import transformer_adapter
from repro.core.server import FederatedServer
from repro.data.partition import partition_clients
from repro.data.synthetic import make_token_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCH_IDS)
    ap.add_argument("--rounds", type=int, default=8)
    args = ap.parse_args()

    mcfg = get_smoke_config(args.arch)
    cfg = FLConfig(num_clients=20, num_clusters=5, select_ratio=0.25,
                   rounds=args.rounds, lr=0.1, non_iid_level=1.0,
                   scheme="gradient_cluster_auction", num_classes=10,
                   sample_window=8, cluster_resamples=2,
                   init_energy_mode="normal")
    toks, topics = make_token_dataset(num_topics=10, vocab=mcfg.vocab_size,
                                      seq_len=32, n=800, seed=0)
    clients = partition_clients(topics, cfg, seed=0)
    srv = FederatedServer(cfg, transformer_adapter(mcfg), toks, topics,
                          clients, {"x": toks[:64], "y": topics[:64]})
    logs = srv.run(verbose=True)
    print(f"\n{mcfg.name}: LM loss {logs[0].test_loss:.3f} -> "
          f"{logs[-1].test_loss:.3f} over {args.rounds} FL rounds; "
          f"energy std {logs[-1].energy_std:.3f}")


if __name__ == "__main__":
    main()
